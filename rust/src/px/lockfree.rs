//! Lock-free scheduling structures for the HPX-thread manager hot path
//! (DESIGN.md §2.1; the park/wake eventcount built on top of these is
//! §2.2, and what each contention counter means afterwards is §2.3).
//!
//! Two primitives, both hand-rolled on std atomics (no `crossbeam-deque`
//! in the offline build):
//!
//! * [`WsDeque`] — a Chase–Lev work-stealing deque (Chase & Lev 2005,
//!   with the weak-memory orderings of Lê et al. 2013). The owning
//!   worker pushes and pops at the *bottom* with no atomic RMW except on
//!   the final element; thieves steal the *oldest* task from the *top*
//!   with a single CAS. The buffer grows geometrically; retired buffers
//!   are kept alive until the deque drops, so a thief holding a stale
//!   buffer pointer can always complete its read (the element it reads
//!   is validated by the subsequent CAS on `top`).
//!
//! * [`MpmcQueue`] — a Vyukov-style bounded MPMC ring (per-slot sequence
//!   numbers, one CAS per push/pop) with an overflow spillover list for
//!   bursts beyond the ring capacity, used as the *injector* for spawns
//!   arriving from off-pool OS threads and as the shared global queue.
//!   Per-producer FIFO is preserved across the ring/overflow boundary:
//!   one producer's pushes are consumed in push order (once its push
//!   overflows, its later pushes also overflow until consumers drain
//!   the spillover). Pushes from *different* producers carry no order
//!   relative to each other — racing the spill transition can consume
//!   producer B's newer element before producer A's older one, which is
//!   the same (absent) guarantee any MPMC queue gives unordered
//!   producers.
//!
//! Both report contention to the caller ([`QStats`]), split by kind so
//! the performance counters keep distinct meanings: CAS conflicts feed
//! `queue_cas_retries` (the lock-free analogue of lock contention) and
//! spillover-lock conflicts feed `queue_contended` (actual lock
//! contention, ~0 by construction).
//!
//! Safety model: slots hold thin raw pointers (`Box<T>` leaked into the
//! slot, reconstructed exactly once on the consuming side). `WsDeque`
//! ownership discipline — `push`/`pop` only from the owning worker
//! thread, `steal` from anywhere — is enforced by the scheduler
//! (`sched::LocalPriority`), which routes only hint-matching, on-pool
//! spawns to the deque.
//!
//! Deliberate tradeoff: boxing each element costs one small allocation
//! per push that inline `MaybeUninit` slot storage (crossbeam's choice)
//! would avoid. Inline storage requires a thief to read a slot the owner
//! may concurrently overwrite and discard the value on CAS failure — a
//! technical data race under the C++11 model that crossbeam accepts and
//! we, hand-rolling without miri/loom in the build environment, do not.
//! The pointer-slot variant keeps every cross-thread handoff an atomic
//! operation. Revisit if fig9 profiles show the allocator on the hot
//! path.

use std::collections::VecDeque;
use std::sync::atomic::{fence, AtomicIsize, AtomicPtr, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::util::CachePadded;

// ------------------------------------------------------------- WsDeque

struct WsBuf<T> {
    slots: Box<[AtomicPtr<T>]>,
    mask: isize,
}

impl<T> WsBuf<T> {
    fn new(cap: usize) -> WsBuf<T> {
        debug_assert!(cap.is_power_of_two());
        WsBuf {
            slots: (0..cap).map(|_| AtomicPtr::new(std::ptr::null_mut())).collect(),
            mask: cap as isize - 1,
        }
    }

    fn cap(&self) -> isize {
        self.mask + 1
    }

    #[inline]
    fn put(&self, i: isize, p: *mut T) {
        self.slots[(i & self.mask) as usize].store(p, Ordering::Relaxed);
    }

    #[inline]
    fn get(&self, i: isize) -> *mut T {
        self.slots[(i & self.mask) as usize].load(Ordering::Relaxed)
    }
}

/// Outcome of a steal attempt.
pub enum Steal<T> {
    /// Nothing to steal.
    Empty,
    /// Took the victim's oldest element.
    Taken(T),
    /// Lost a race with the owner or another thief; worth retrying.
    Contended,
}

/// Chase–Lev work-stealing deque. See module docs for the ownership
/// discipline (single pusher/popper, many stealers).
pub struct WsDeque<T> {
    top: CachePadded<AtomicIsize>,
    bottom: CachePadded<AtomicIsize>,
    buf: AtomicPtr<WsBuf<T>>,
    /// Buffers replaced by growth; freed on drop (bounded: caps double,
    /// so all retired buffers together are smaller than the live one).
    retired: Mutex<Vec<*mut WsBuf<T>>>,
}

// Raw pointers make these !Send/!Sync by default; the protocol above
// makes shared access sound, and T: Send gates the payloads.
unsafe impl<T: Send> Send for WsDeque<T> {}
unsafe impl<T: Send> Sync for WsDeque<T> {}

impl<T> Default for WsDeque<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> WsDeque<T> {
    /// New empty deque (initial capacity 64).
    pub fn new() -> WsDeque<T> {
        WsDeque {
            top: CachePadded::new(AtomicIsize::new(0)),
            bottom: CachePadded::new(AtomicIsize::new(0)),
            buf: AtomicPtr::new(Box::into_raw(Box::new(WsBuf::new(64)))),
            retired: Mutex::new(Vec::new()),
        }
    }

    /// Approximate number of queued elements (diagnostics only).
    pub fn len(&self) -> usize {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Relaxed);
        (b - t).max(0) as usize
    }

    /// True when (approximately) empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Owner-only: push at the bottom. Returns the new approximate
    /// length (for high-water-mark accounting).
    pub fn push(&self, value: T) -> usize {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        // Only the owner swaps `buf`, so a Relaxed load is its own write.
        let mut buf = unsafe { &*self.buf.load(Ordering::Relaxed) };
        if b - t >= buf.cap() {
            buf = self.grow(t, b, buf);
        }
        buf.put(b, Box::into_raw(Box::new(value)));
        // Publish the slot write before the new bottom becomes visible.
        self.bottom.store(b + 1, Ordering::Release);
        (b + 1 - t).max(0) as usize
    }

    /// Owner-only: pop at the bottom (LIFO — best cache locality for the
    /// task the owner just created).
    pub fn pop(&self) -> Option<T> {
        let b = self.bottom.load(Ordering::Relaxed) - 1;
        self.bottom.store(b, Ordering::Relaxed);
        // The SeqCst fence orders the speculative bottom claim against
        // thieves' top reads (Dekker-style).
        fence(Ordering::SeqCst);
        let t = self.top.load(Ordering::Relaxed);
        if t > b {
            // Empty: undo the claim.
            self.bottom.store(b + 1, Ordering::Relaxed);
            return None;
        }
        let buf = unsafe { &*self.buf.load(Ordering::Relaxed) };
        let p = buf.get(b);
        if t == b {
            // Last element: race the thieves for it via top.
            let won = self
                .top
                .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                .is_ok();
            self.bottom.store(b + 1, Ordering::Relaxed);
            if !won {
                return None; // a thief got it
            }
        }
        Some(*unsafe { Box::from_raw(p) })
    }

    /// Any thread: steal the oldest element.
    pub fn steal(&self) -> Steal<T> {
        let t = self.top.load(Ordering::Acquire);
        fence(Ordering::SeqCst);
        let b = self.bottom.load(Ordering::Acquire);
        if t >= b {
            return Steal::Empty;
        }
        // Read the slot *before* the CAS: succeeding at the CAS proves
        // element `t` had not been taken, and retired buffers stay alive,
        // so the read pointer is the element even across a growth race.
        let buf = unsafe { &*self.buf.load(Ordering::Acquire) };
        let p = buf.get(t);
        if self
            .top
            .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
            .is_ok()
        {
            Steal::Taken(*unsafe { Box::from_raw(p) })
        } else {
            Steal::Contended
        }
    }

    /// Owner-only, cold path: double the buffer, copying live elements.
    fn grow(&self, t: isize, b: isize, old: &WsBuf<T>) -> &WsBuf<T> {
        let new = Box::new(WsBuf::new((old.cap() as usize) * 2));
        for i in t..b {
            new.put(i, old.get(i));
        }
        let new_ptr = Box::into_raw(new);
        let old_ptr = self.buf.swap(new_ptr, Ordering::Release);
        self.retired.lock().unwrap().push(old_ptr);
        unsafe { &*new_ptr }
    }
}

impl<T> Drop for WsDeque<T> {
    fn drop(&mut self) {
        // Exclusive access here: free remaining elements, then buffers.
        let t = self.top.load(Ordering::Relaxed);
        let b = self.bottom.load(Ordering::Relaxed);
        let buf = unsafe { Box::from_raw(self.buf.load(Ordering::Relaxed)) };
        for i in t..b {
            drop(unsafe { Box::from_raw(buf.get(i)) });
        }
        for p in self.retired.lock().unwrap().drain(..) {
            drop(unsafe { Box::from_raw(p) });
        }
    }
}

// ----------------------------------------------------------- MpmcQueue

struct MpmcCell<T> {
    seq: AtomicUsize,
    val: AtomicPtr<T>,
}

/// Contention record for one [`MpmcQueue`] operation, split by kind so
/// the performance counters keep distinct meanings: `cas_retries` are
/// lock-free conflicts (another core won the cursor race), while
/// `lock_contended` are failed `try_lock`s on the overflow spillover —
/// the only lock anywhere near the hot path, and only under sustained
/// ring overflow.
#[derive(Default, Debug, Clone, Copy)]
pub struct QStats {
    pub cas_retries: u64,
    pub lock_contended: u64,
}

/// Vyukov bounded MPMC ring + FIFO-preserving overflow spillover.
///
/// Push and pop are one CAS each on the hot path. When the ring fills
/// (sustained producer surplus), pushes divert to a mutex-guarded list;
/// consumers drain the ring first (it holds the older elements), so FIFO
/// order per queue is preserved.
pub struct MpmcQueue<T> {
    cells: Box<[MpmcCell<T>]>,
    mask: usize,
    enq: CachePadded<AtomicUsize>,
    deq: CachePadded<AtomicUsize>,
    /// Approximate live count (ring + overflow), for len/hwm accounting.
    count: CachePadded<AtomicUsize>,
    /// Set while the overflow list may be non-empty.
    overflowed: AtomicUsize,
    overflow: Mutex<VecDeque<T>>,
}

unsafe impl<T: Send> Send for MpmcQueue<T> {}
unsafe impl<T: Send> Sync for MpmcQueue<T> {}

impl<T> MpmcQueue<T> {
    /// Ring of `cap` slots (rounded up to a power of two, min 8).
    pub fn with_capacity(cap: usize) -> MpmcQueue<T> {
        let cap = cap.next_power_of_two().max(8);
        MpmcQueue {
            cells: (0..cap)
                .map(|i| MpmcCell { seq: AtomicUsize::new(i), val: AtomicPtr::new(std::ptr::null_mut()) })
                .collect(),
            mask: cap - 1,
            enq: CachePadded::new(AtomicUsize::new(0)),
            deq: CachePadded::new(AtomicUsize::new(0)),
            count: CachePadded::new(AtomicUsize::new(0)),
            overflowed: AtomicUsize::new(0),
            overflow: Mutex::new(VecDeque::new()),
        }
    }

    /// Approximate queued elements.
    pub fn len(&self) -> usize {
        self.count.load(Ordering::Relaxed)
    }

    /// True when (approximately) empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enqueue. Returns the approximate post-push length; records
    /// conflicts in `stats`.
    pub fn push(&self, value: T, stats: &mut QStats) -> usize {
        if self.overflowed.load(Ordering::Acquire) == 0 {
            let boxed = Box::new(value);
            let mut pos = self.enq.load(Ordering::Relaxed);
            loop {
                let cell = &self.cells[pos & self.mask];
                let seq = cell.seq.load(Ordering::Acquire);
                let dif = (seq as isize).wrapping_sub(pos as isize);
                if dif == 0 {
                    match self.enq.compare_exchange_weak(
                        pos,
                        pos.wrapping_add(1),
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                    ) {
                        Ok(_) => {
                            cell.val.store(Box::into_raw(boxed), Ordering::Relaxed);
                            cell.seq.store(pos.wrapping_add(1), Ordering::Release);
                            return self.count.fetch_add(1, Ordering::Relaxed) + 1;
                        }
                        Err(cur) => {
                            stats.cas_retries += 1;
                            pos = cur;
                        }
                    }
                } else if dif < 0 {
                    // Ring full: spill. (Re-take ownership of the value.)
                    self.spill(*boxed, stats);
                    return self.count.fetch_add(1, Ordering::Relaxed) + 1;
                } else {
                    pos = self.enq.load(Ordering::Relaxed);
                }
            }
        } else {
            // Overflow already engaged: keep FIFO by appending there.
            let mut g = self.lock_overflow(stats);
            // Re-assert the flag under the lock: a consumer may have
            // drained the list and cleared it between our load above and
            // taking the lock — without this store the appended element
            // would be invisible to `pop` (stranded task = deadlock, now
            // that parking has no timeout to paper over lost work).
            self.overflowed.store(1, Ordering::Release);
            g.push_back(value);
            drop(g);
            self.count.fetch_add(1, Ordering::Relaxed) + 1
        }
    }

    /// Dequeue. Records conflicts in `stats`.
    pub fn pop(&self, stats: &mut QStats) -> Option<T> {
        let mut pos = self.deq.load(Ordering::Relaxed);
        loop {
            let cell = &self.cells[pos & self.mask];
            let seq = cell.seq.load(Ordering::Acquire);
            let dif = (seq as isize).wrapping_sub(pos.wrapping_add(1) as isize);
            if dif == 0 {
                match self.deq.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // The producer's Release store of seq ordered the
                        // val store before it; spin the (tiny) window where
                        // seq is published but val not yet visible is
                        // impossible by that ordering.
                        let p = cell.val.swap(std::ptr::null_mut(), Ordering::Acquire);
                        debug_assert!(!p.is_null());
                        cell.seq.store(pos.wrapping_add(self.mask + 1), Ordering::Release);
                        self.count.fetch_sub(1, Ordering::Relaxed);
                        return Some(*unsafe { Box::from_raw(p) });
                    }
                    Err(cur) => {
                        stats.cas_retries += 1;
                        pos = cur;
                    }
                }
            } else if dif < 0 {
                // Ring empty; check the spillover.
                if self.overflowed.load(Ordering::Acquire) != 0 {
                    let mut g = self.lock_overflow(stats);
                    if let Some(v) = g.pop_front() {
                        if g.is_empty() {
                            self.overflowed.store(0, Ordering::Release);
                        }
                        drop(g);
                        self.count.fetch_sub(1, Ordering::Relaxed);
                        return Some(v);
                    }
                    self.overflowed.store(0, Ordering::Release);
                    return None;
                }
                return None;
            } else {
                pos = self.deq.load(Ordering::Relaxed);
            }
        }
    }

    /// Acquire the overflow lock, counting a failed `try_lock`.
    fn lock_overflow(&self, stats: &mut QStats) -> std::sync::MutexGuard<'_, VecDeque<T>> {
        match self.overflow.try_lock() {
            Ok(g) => g,
            Err(std::sync::TryLockError::WouldBlock) => {
                stats.lock_contended += 1;
                self.overflow.lock().unwrap()
            }
            Err(std::sync::TryLockError::Poisoned(e)) => e.into_inner(),
        }
    }

    /// Cold path of [`MpmcQueue::push`]: divert to the overflow list.
    fn spill(&self, value: T, stats: &mut QStats) {
        let mut g = self.lock_overflow(stats);
        self.overflowed.store(1, Ordering::Release);
        g.push_back(value);
    }
}

impl<T> Drop for MpmcQueue<T> {
    fn drop(&mut self) {
        let mut s = QStats::default();
        while self.pop(&mut s).is_some() {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::Arc;

    #[test]
    fn ws_deque_lifo_for_owner() {
        let d: WsDeque<u32> = WsDeque::new();
        d.push(1);
        d.push(2);
        d.push(3);
        assert_eq!(d.len(), 3);
        assert_eq!(d.pop(), Some(3));
        assert_eq!(d.pop(), Some(2));
        assert_eq!(d.pop(), Some(1));
        assert_eq!(d.pop(), None);
        assert_eq!(d.pop(), None); // repeated empty pops stay consistent
        d.push(9);
        assert_eq!(d.pop(), Some(9));
    }

    #[test]
    fn ws_deque_steal_takes_oldest() {
        let d: WsDeque<u32> = WsDeque::new();
        d.push(1);
        d.push(2);
        match d.steal() {
            Steal::Taken(v) => assert_eq!(v, 1),
            _ => panic!("expected steal"),
        }
        assert_eq!(d.pop(), Some(2));
        assert!(matches!(d.steal(), Steal::Empty));
    }

    #[test]
    fn ws_deque_grows_past_initial_capacity() {
        let d: WsDeque<usize> = WsDeque::new();
        for i in 0..1000 {
            d.push(i);
        }
        assert_eq!(d.len(), 1000);
        // Steals drain FIFO from the top.
        for want in 0..500 {
            match d.steal() {
                Steal::Taken(v) => assert_eq!(v, want),
                _ => panic!("steal {want}"),
            }
        }
        // Owner drains LIFO from the bottom.
        for want in (500..1000).rev() {
            assert_eq!(d.pop(), Some(want));
        }
        assert_eq!(d.pop(), None);
    }

    #[test]
    fn ws_deque_drop_frees_leftovers() {
        let d: WsDeque<Vec<u8>> = WsDeque::new();
        for _ in 0..100 {
            d.push(vec![0u8; 128]);
        }
        drop(d); // leak-checked under miri/asan builds
    }

    #[test]
    fn ws_deque_owner_vs_thieves_exactly_once() {
        let d: Arc<WsDeque<u64>> = Arc::new(WsDeque::new());
        let sum = Arc::new(AtomicU64::new(0));
        let taken = Arc::new(AtomicU64::new(0));
        const N: u64 = 100_000;
        let thieves: Vec<_> = (0..3)
            .map(|_| {
                let d = d.clone();
                let sum = sum.clone();
                let taken = taken.clone();
                std::thread::spawn(move || loop {
                    match d.steal() {
                        Steal::Taken(v) => {
                            sum.fetch_add(v, Ordering::Relaxed);
                            taken.fetch_add(1, Ordering::Relaxed);
                        }
                        Steal::Empty => {
                            if taken.load(Ordering::Acquire) >= N {
                                break;
                            }
                            std::hint::spin_loop();
                        }
                        Steal::Contended => std::hint::spin_loop(),
                    }
                })
            })
            .collect();
        // Owner interleaves pushes and pops.
        let mut next = 1u64;
        while next <= N {
            for _ in 0..64 {
                if next > N {
                    break;
                }
                d.push(next);
                next += 1;
            }
            while let Some(v) = d.pop() {
                sum.fetch_add(v, Ordering::Relaxed);
                taken.fetch_add(1, Ordering::Relaxed);
            }
        }
        while let Some(v) = d.pop() {
            sum.fetch_add(v, Ordering::Relaxed);
            taken.fetch_add(1, Ordering::Relaxed);
        }
        for t in thieves {
            t.join().unwrap();
        }
        assert_eq!(taken.load(Ordering::SeqCst), N);
        assert_eq!(sum.load(Ordering::SeqCst), N * (N + 1) / 2);
    }

    #[test]
    fn mpmc_fifo_single_thread() {
        let q: MpmcQueue<u32> = MpmcQueue::with_capacity(8);
        let mut s = QStats::default();
        for i in 0..5 {
            q.push(i, &mut s);
        }
        for i in 0..5 {
            assert_eq!(q.pop(&mut s), Some(i));
        }
        assert_eq!(q.pop(&mut s), None);
    }

    #[test]
    fn mpmc_overflow_preserves_fifo() {
        let q: MpmcQueue<u32> = MpmcQueue::with_capacity(8);
        let mut s = QStats::default();
        for i in 0..100 {
            q.push(i, &mut s); // 8-slot ring: 92 spill
        }
        assert_eq!(q.len(), 100);
        for i in 0..100 {
            assert_eq!(q.pop(&mut s), Some(i), "at {i}");
        }
        assert_eq!(q.pop(&mut s), None);
        // After draining, the ring is usable again.
        q.push(7, &mut s);
        assert_eq!(q.pop(&mut s), Some(7));
    }

    #[test]
    fn mpmc_concurrent_producers_consumers_exactly_once() {
        let q: Arc<MpmcQueue<u64>> = Arc::new(MpmcQueue::with_capacity(256));
        const PER: u64 = 50_000;
        const PRODUCERS: u64 = 4;
        let producers: Vec<_> = (0..PRODUCERS)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut s = QStats::default();
                    for i in 0..PER {
                        q.push(p * PER + i, &mut s);
                    }
                })
            })
            .collect();
        let got = Arc::new(AtomicU64::new(0));
        let sum = Arc::new(AtomicU64::new(0));
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                let got = got.clone();
                let sum = sum.clone();
                std::thread::spawn(move || {
                    let mut s = QStats::default();
                    while got.load(Ordering::Acquire) < PRODUCERS * PER {
                        if let Some(v) = q.pop(&mut s) {
                            sum.fetch_add(v, Ordering::Relaxed);
                            got.fetch_add(1, Ordering::Relaxed);
                        } else {
                            std::hint::spin_loop();
                        }
                    }
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        for c in consumers {
            c.join().unwrap();
        }
        let n = PRODUCERS * PER;
        assert_eq!(got.load(Ordering::SeqCst), n);
        assert_eq!(sum.load(Ordering::SeqCst), n * (n - 1) / 2);
    }

    #[test]
    fn mpmc_drop_frees_leftovers() {
        let q: MpmcQueue<String> = MpmcQueue::with_capacity(8);
        let mut s = QStats::default();
        for i in 0..40 {
            q.push(format!("item-{i}"), &mut s);
        }
        drop(q);
    }
}
