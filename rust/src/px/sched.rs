//! Scheduling policies for the HPX-thread manager.
//!
//! The paper names two policies implemented by HPX's thread manager:
//! a **global queue** scheduler ("all cores pull their work from a single,
//! global queue") and a **local priority** scheduler ("each core pulls its
//! work from a separate priority queue … supports work stealing for better
//! load balancing"). Both are provided here behind the [`Policy`] trait so
//! the Fig 9 overhead bench and the AMR drivers can swap them at runtime.
//!
//! Since the lock-free rebuild (DESIGN.md §2) the hot paths take no
//! locks:
//!
//! * [`GlobalQueue`] — one shared Vyukov MPMC ring per priority class.
//!   Still the paper's contention demonstrator: every core hammers the
//!   same enqueue/dequeue cursors, and each CAS lost to another core is
//!   recorded in `queue_cas_retries` (the lock-free analogue of the old
//!   `try_lock` accounting; `queue_contended` now only counts lock
//!   acquisitions that contended, i.e. the overflow spillover).
//! * [`LocalPriority`] — per-worker Chase–Lev deques (one per priority)
//!   plus a shared injector for off-pool spawns. On-pool spawn/pop touch
//!   only the owner's deque ends; thieves take the victim's *oldest*
//!   task with one CAS. `steals` counts successful steals,
//!   `queue_cas_retries` counts lost cursor/steal races, and
//!   `queue_contended` (locks that had to contend) stays ~0 by
//!   construction — only the injector's overflow spillover lock remains.
//! * [`MutexQueue`] — the pre-refactor `Mutex<VecDeque>` global queue,
//!   retained verbatim as the perf-trajectory baseline for
//!   `BENCH_1.json` (and as a behavioural reference in tests).
//!
//! The primitives behind both lock-free policies live in
//! [`crate::px::lockfree`]; the park/wake eventcount that lets idle
//! workers sleep without a poll loop is in [`crate::px::thread`]
//! (DESIGN.md §2.2), and DESIGN.md §2.3 tabulates what every counter
//! measures after the rebuild.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::CachePadded;

use super::counters::Counters;
use super::lockfree::{MpmcQueue, QStats, Steal, WsDeque};
use super::thread::Spawner;

/// PX-thread priority. High drains before Normal before Low within a
/// queue; stealing ignores priority (steals the victim's oldest work).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Priority {
    /// Runtime-internal work (parcel decode, LCO triggers).
    High = 0,
    /// Application PX-threads.
    Normal = 1,
    /// Background work (regridding hints, diagnostics).
    Low = 2,
}

/// A ready-to-run PX-thread.
pub struct Task {
    pub prio: Priority,
    /// Trace span id when the flight recorder was enabled at spawn time
    /// (0 otherwise — spans are never 0). Rides with the task so the
    /// begin/end events on the executing worker and the steal event on
    /// the thief name the same DAG node as the spawn edge.
    pub span: u64,
    pub f: Box<dyn FnOnce(&Spawner) + Send>,
}

/// A scheduling policy: where spawned tasks go and where workers look.
pub trait Policy: Send + Sync {
    /// Enqueue a task. `hint` is the spawning worker's index when the
    /// spawn originated on-pool *on this manager* (used by local queues
    /// for affinity; the thread manager guarantees a `Some(w)` hint is
    /// only ever passed from worker `w`'s own OS thread).
    fn push(&self, task: Task, hint: Option<usize>);
    /// Dequeue work for worker `w` (may steal). `None` = nothing runnable.
    fn pop(&self, w: usize) -> Option<Task>;
    /// Approximate total queued tasks (diagnostics only).
    fn approx_len(&self) -> usize;
}

/// Ring capacities per priority class for the shared MPMC queues.
/// `GlobalQueue` carries *all* traffic, so its Normal ring absorbs most
/// of a Fig 9 burst (one producer outrunning one worker) on the
/// lock-free path (~1 MB of cells). The `LocalPriority` injector only
/// carries off-pool spawns, so it gets a much smaller ring (~250 KB);
/// extreme bursts degrade gracefully through the FIFO overflow
/// spillover, whose lock conflicts are honestly reported as
/// `queue_contended`. High/Low see far less traffic everywhere.
const RING_NORMAL_GLOBAL: usize = 1 << 16;
const RING_NORMAL_INJECTOR: usize = 1 << 13;
const RING_OTHER: usize = 1 << 12;

/// Three MPMC queues, one per priority class.
struct PrioMpmc {
    qs: [MpmcQueue<Task>; 3],
    counters: Arc<Counters>,
}

impl PrioMpmc {
    fn new(counters: Arc<Counters>, normal_cap: usize) -> PrioMpmc {
        PrioMpmc {
            qs: [
                MpmcQueue::with_capacity(RING_OTHER),
                MpmcQueue::with_capacity(normal_cap),
                MpmcQueue::with_capacity(RING_OTHER),
            ],
            counters,
        }
    }

    fn record(&self, s: QStats) {
        if s.cas_retries > 0 {
            self.counters.queue_cas_retries.add(s.cas_retries);
        }
        if s.lock_contended > 0 {
            self.counters.queue_contended.add(s.lock_contended);
        }
    }

    fn push(&self, task: Task) {
        let mut s = QStats::default();
        let len = self.qs[task.prio as usize].push(task, &mut s);
        self.record(s);
        self.counters.queue_hwm.max(len as u64);
    }

    fn pop(&self) -> Option<Task> {
        let mut s = QStats::default();
        let mut out = None;
        for q in &self.qs {
            if let Some(t) = q.pop(&mut s) {
                out = Some(t);
                break;
            }
        }
        self.record(s);
        out
    }

    fn len(&self) -> usize {
        self.qs.iter().map(|q| q.len()).sum()
    }
}

/// Single lock-free FIFO (per priority) shared by all workers.
///
/// Fair and simple, but every core contends on the same two cursors as
/// core counts grow — exactly the effect the Fig 9 bench demonstrates,
/// now visible as CAS conflicts (`queue_cas_retries`) and cache-line
/// ping-pong instead of a mutex convoy.
pub struct GlobalQueue {
    shared: PrioMpmc,
}

impl GlobalQueue {
    pub fn new(counters: Arc<Counters>) -> Self {
        GlobalQueue { shared: PrioMpmc::new(counters, RING_NORMAL_GLOBAL) }
    }
}

impl Policy for GlobalQueue {
    fn push(&self, task: Task, _hint: Option<usize>) {
        self.shared.push(task);
    }

    fn pop(&self, _w: usize) -> Option<Task> {
        self.shared.pop()
    }

    fn approx_len(&self) -> usize {
        self.shared.len()
    }
}

/// Per-worker Chase–Lev deques (one per priority class) with work
/// stealing, plus a shared injector queue for spawns arriving from
/// off-pool OS threads (parcel port, main, LCO triggers off-pool).
pub struct LocalPriority {
    /// `locals[w]` is owned by worker `w`: push/pop only from that
    /// worker's OS thread, steal from anywhere.
    locals: Vec<CachePadded<[WsDeque<Task>; 3]>>,
    injector: PrioMpmc,
    /// Rotates the first steal victim so repeated failed rounds don't
    /// all hammer worker w+1.
    steal_rr: AtomicUsize,
    counters: Arc<Counters>,
}

impl LocalPriority {
    pub fn new(n_workers: usize, counters: Arc<Counters>) -> Self {
        LocalPriority {
            locals: (0..n_workers)
                .map(|_| CachePadded::new([WsDeque::new(), WsDeque::new(), WsDeque::new()]))
                .collect(),
            injector: PrioMpmc::new(counters.clone(), RING_NORMAL_INJECTOR),
            steal_rr: AtomicUsize::new(0),
            counters,
        }
    }

    /// One full steal sweep over the other workers' deques, oldest task
    /// first, priority classes high-to-low per victim.
    fn try_steal(&self, w: usize) -> Option<Task> {
        let n = self.locals.len();
        if n <= 1 {
            return None;
        }
        let start = self.steal_rr.fetch_add(1, Ordering::Relaxed);
        for off in 0..n - 1 {
            // Victims cycle over every worker except `w`.
            let v = (w + 1 + (start + off) % (n - 1)) % n;
            for q in self.locals[v].iter() {
                let mut spins = 0u32;
                loop {
                    match q.steal() {
                        Steal::Taken(t) => {
                            self.counters.steals.inc();
                            if t.span != 0 {
                                super::trace::steal(t.span);
                            }
                            return Some(t);
                        }
                        Steal::Empty => break,
                        Steal::Contended => {
                            // Another core won the race; retry briefly,
                            // then move to the next victim.
                            self.counters.queue_cas_retries.inc();
                            spins += 1;
                            if spins >= 4 {
                                break;
                            }
                            std::hint::spin_loop();
                        }
                    }
                }
            }
        }
        None
    }
}

impl Policy for LocalPriority {
    fn push(&self, task: Task, hint: Option<usize>) {
        match hint {
            // On-pool spawn: owner-push onto the spawning worker's own
            // deque — no atomic RMW, no sharing (until stolen).
            Some(w) => {
                let len = self.locals[w][task.prio as usize].push(task);
                self.counters.queue_hwm.max(len as u64);
            }
            // Off-pool spawn: shared injector (workers drain it when
            // their own deques run dry; it spreads naturally).
            None => self.injector.push(task),
        }
    }

    fn pop(&self, w: usize) -> Option<Task> {
        // 1. Own deques, highest priority first (LIFO within a class:
        //    freshest task has the hottest cache).
        for q in self.locals[w].iter() {
            if let Some(t) = q.pop() {
                return Some(t);
            }
        }
        // 2. Injector (off-pool arrivals), priority order.
        if let Some(t) = self.injector.pop() {
            return Some(t);
        }
        // 3. Steal the oldest work from a victim (largest expected
        //    remaining subtree, lowest steal frequency).
        self.try_steal(w)
    }

    fn approx_len(&self) -> usize {
        let mut n = self.injector.len();
        for l in &self.locals {
            n += l.iter().map(|q| q.len()).sum::<usize>();
        }
        n
    }
}

// ------------------------------------------------- MutexQueue (baseline)

type PrioQueues = [VecDeque<Task>; 3];

fn push_prio(qs: &mut PrioQueues, task: Task) {
    qs[task.prio as usize].push_back(task);
}

fn pop_prio(qs: &mut PrioQueues) -> Option<Task> {
    for q in qs.iter_mut() {
        if let Some(t) = q.pop_front() {
            return Some(t);
        }
    }
    None
}

fn len_prio(qs: &PrioQueues) -> usize {
    qs.iter().map(|q| q.len()).sum()
}

/// The pre-refactor global queue: a single `Mutex<VecDeque>` per run,
/// with failed-`try_lock` contention accounting. Kept as the measured
/// baseline the lock-free schedulers are compared against in
/// `BENCH_1.json` (`bench::fig9_bench_json`).
pub struct MutexQueue {
    queues: Mutex<PrioQueues>,
    counters: Arc<Counters>,
}

impl MutexQueue {
    pub fn new(counters: Arc<Counters>) -> Self {
        MutexQueue { queues: Mutex::new(Default::default()), counters }
    }

    /// Lock with contention accounting: a failed `try_lock` is counted
    /// before falling back to a blocking acquire.
    fn lock(&self) -> std::sync::MutexGuard<'_, PrioQueues> {
        match self.queues.try_lock() {
            Ok(g) => g,
            Err(_) => {
                self.counters.queue_contended.inc();
                self.queues.lock().unwrap()
            }
        }
    }
}

impl Policy for MutexQueue {
    fn push(&self, task: Task, _hint: Option<usize>) {
        let mut g = self.lock();
        push_prio(&mut g, task);
        let n = len_prio(&g) as u64;
        self.counters.queue_hwm.max(n);
    }

    fn pop(&self, _w: usize) -> Option<Task> {
        pop_prio(&mut self.lock())
    }

    fn approx_len(&self) -> usize {
        len_prio(&self.queues.lock().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(prio: Priority) -> Task {
        Task { prio, span: 0, f: Box::new(|_| {}) }
    }

    #[test]
    fn global_queue_fifo_within_priority() {
        let c = Arc::new(Counters::default());
        let q = GlobalQueue::new(c);
        let seen = Arc::new(Mutex::new(Vec::new()));
        for i in 0..3 {
            let seen = seen.clone();
            q.push(
                Task {
                    prio: Priority::Normal,
                    span: 0,
                    f: Box::new(move |_| seen.lock().unwrap().push(i)),
                },
                None,
            );
        }
        assert_eq!(q.approx_len(), 3);
        // Pop order must match push order (FIFO); we can't call f without a
        // Spawner here, so check by draining lengths only.
        assert!(q.pop(0).is_some());
        assert_eq!(q.approx_len(), 2);
    }

    #[test]
    fn global_queue_priority_order() {
        let q = GlobalQueue::new(Arc::new(Counters::default()));
        q.push(task(Priority::Low), None);
        q.push(task(Priority::High), None);
        q.push(task(Priority::Normal), None);
        assert_eq!(q.pop(0).unwrap().prio, Priority::High);
        assert_eq!(q.pop(0).unwrap().prio, Priority::Normal);
        assert_eq!(q.pop(0).unwrap().prio, Priority::Low);
        assert!(q.pop(0).is_none());
    }

    #[test]
    fn mutex_queue_priority_order() {
        let q = MutexQueue::new(Arc::new(Counters::default()));
        q.push(task(Priority::Low), None);
        q.push(task(Priority::High), None);
        q.push(task(Priority::Normal), None);
        assert_eq!(q.pop(0).unwrap().prio, Priority::High);
        assert_eq!(q.pop(0).unwrap().prio, Priority::Normal);
        assert_eq!(q.pop(0).unwrap().prio, Priority::Low);
        assert!(q.pop(0).is_none());
    }

    #[test]
    fn local_priority_hint_lands_on_that_worker() {
        let q = LocalPriority::new(4, Arc::new(Counters::default()));
        q.push(task(Priority::Normal), Some(2));
        // Worker 2 gets it without stealing.
        let c_before = q.counters.steals.get();
        assert!(q.pop(2).is_some());
        assert_eq!(q.counters.steals.get(), c_before);
    }

    #[test]
    fn local_priority_steal_from_any_victim() {
        let q = LocalPriority::new(4, Arc::new(Counters::default()));
        q.push(task(Priority::Normal), Some(0));
        // Worker 3 finds nothing local, must steal from 0.
        assert!(q.pop(3).is_some());
        assert_eq!(q.counters.steals.get(), 1);
        assert!(q.pop(3).is_none());
    }

    #[test]
    fn local_priority_offpool_pushes_land_in_injector() {
        let q = LocalPriority::new(4, Arc::new(Counters::default()));
        for _ in 0..8 {
            q.push(task(Priority::Normal), None);
        }
        // Every worker drains the shared injector directly: no steals.
        for w in 0..4 {
            assert!(q.pop(w).is_some(), "worker {w} empty");
        }
        assert_eq!(q.counters.steals.get(), 0);
    }

    #[test]
    fn local_priority_own_queue_preferred_over_injector_and_steal() {
        let q = LocalPriority::new(2, Arc::new(Counters::default()));
        q.push(task(Priority::Low), None); // injector
        q.push(task(Priority::Normal), Some(0)); // own
        // Worker 0 must take its own Normal task before the injected Low.
        assert_eq!(q.pop(0).unwrap().prio, Priority::Normal);
        assert_eq!(q.pop(0).unwrap().prio, Priority::Low);
    }

    #[test]
    fn local_priority_steal_takes_oldest() {
        let q = LocalPriority::new(2, Arc::new(Counters::default()));
        let order = Arc::new(Mutex::new(Vec::new()));
        for i in 0..3 {
            let order = order.clone();
            q.push(
                Task {
                    prio: Priority::Normal,
                    span: 0,
                    f: Box::new(move |_| order.lock().unwrap().push(i)),
                },
                Some(0),
            );
        }
        // Worker 1 steals the oldest (i=0); worker 0 pops the newest.
        assert!(q.pop(1).is_some());
        assert_eq!(q.counters.steals.get(), 1);
        assert!(q.pop(0).is_some());
        assert_eq!(q.approx_len(), 1);
    }

    #[test]
    fn hwm_tracks_longest_queue() {
        let c = Arc::new(Counters::default());
        let q = GlobalQueue::new(c.clone());
        for _ in 0..10 {
            q.push(task(Priority::Normal), None);
        }
        assert_eq!(c.queue_hwm.get(), 10);
    }

    #[test]
    fn hwm_tracks_local_deque_depth() {
        let c = Arc::new(Counters::default());
        let q = LocalPriority::new(2, c.clone());
        for _ in 0..7 {
            q.push(task(Priority::Normal), Some(1));
        }
        assert_eq!(c.queue_hwm.get(), 7);
    }

    #[test]
    fn single_worker_local_priority_never_steals_from_itself() {
        let q = LocalPriority::new(1, Arc::new(Counters::default()));
        assert!(q.pop(0).is_none());
        q.push(task(Priority::Normal), Some(0));
        assert!(q.pop(0).is_some());
        assert_eq!(q.counters.steals.get(), 0);
    }
}
