//! Scheduling policies for the HPX-thread manager.
//!
//! The paper names two policies implemented by HPX's thread manager:
//! a **global queue** scheduler ("all cores pull their work from a single,
//! global queue") and a **local priority** scheduler ("each core pulls its
//! work from a separate priority queue … supports work stealing for better
//! load balancing"). Both are provided here behind the [`Policy`] trait so
//! the Fig 9 overhead bench and the AMR drivers can swap them at runtime.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crossbeam_utils::CachePadded;

use super::counters::Counters;
use super::thread::Spawner;

/// PX-thread priority. High drains before Normal before Low within a
/// queue; stealing ignores priority (steals the victim's oldest work).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Priority {
    /// Runtime-internal work (parcel decode, LCO triggers).
    High = 0,
    /// Application PX-threads.
    Normal = 1,
    /// Background work (regridding hints, diagnostics).
    Low = 2,
}

/// A ready-to-run PX-thread.
pub struct Task {
    pub prio: Priority,
    pub f: Box<dyn FnOnce(&Spawner) + Send>,
}

/// A scheduling policy: where spawned tasks go and where workers look.
pub trait Policy: Send + Sync {
    /// Enqueue a task. `hint` is the spawning worker's index when the
    /// spawn originated on-pool (used by local queues for affinity).
    fn push(&self, task: Task, hint: Option<usize>);
    /// Dequeue work for worker `w` (may steal). `None` = nothing runnable.
    fn pop(&self, w: usize) -> Option<Task>;
    /// Approximate total queued tasks (diagnostics only).
    fn approx_len(&self) -> usize;
}

type PrioQueues = [VecDeque<Task>; 3];

fn push_prio(qs: &mut PrioQueues, task: Task) {
    qs[task.prio as usize].push_back(task);
}

fn pop_prio(qs: &mut PrioQueues) -> Option<Task> {
    for q in qs.iter_mut() {
        if let Some(t) = q.pop_front() {
            return Some(t);
        }
    }
    None
}

fn len_prio(qs: &PrioQueues) -> usize {
    qs.iter().map(|q| q.len()).sum()
}

/// Single global FIFO (per priority) shared by all workers.
///
/// Simple and fair, but the single lock becomes the contention point as
/// cores grow — exactly the effect the Fig 9 bench demonstrates.
pub struct GlobalQueue {
    queues: Mutex<PrioQueues>,
    counters: Arc<Counters>,
}

impl GlobalQueue {
    pub fn new(counters: Arc<Counters>) -> Self {
        GlobalQueue { queues: Mutex::new(Default::default()), counters }
    }

    /// Lock with contention accounting: a failed `try_lock` is counted
    /// before falling back to a blocking acquire.
    fn lock(&self) -> std::sync::MutexGuard<'_, PrioQueues> {
        match self.queues.try_lock() {
            Ok(g) => g,
            Err(_) => {
                self.counters.queue_contended.inc();
                self.queues.lock().unwrap()
            }
        }
    }
}

impl Policy for GlobalQueue {
    fn push(&self, task: Task, _hint: Option<usize>) {
        let mut g = self.lock();
        push_prio(&mut g, task);
        let n = len_prio(&g) as u64;
        self.counters.queue_hwm.max(n);
    }

    fn pop(&self, _w: usize) -> Option<Task> {
        pop_prio(&mut self.lock())
    }

    fn approx_len(&self) -> usize {
        len_prio(&self.queues.lock().unwrap())
    }
}

/// Per-worker priority deques with work stealing, plus an injector queue
/// for spawns arriving from off-pool OS threads (parcel port, main).
pub struct LocalPriority {
    locals: Vec<CachePadded<Mutex<PrioQueues>>>,
    injector: Mutex<PrioQueues>,
    /// Round-robin cursor for off-pool pushes without a worker hint.
    rr: AtomicUsize,
    counters: Arc<Counters>,
}

impl LocalPriority {
    pub fn new(n_workers: usize, counters: Arc<Counters>) -> Self {
        LocalPriority {
            locals: (0..n_workers).map(|_| CachePadded::new(Mutex::new(Default::default()))).collect(),
            injector: Mutex::new(Default::default()),
            rr: AtomicUsize::new(0),
            counters,
        }
    }

    fn lock_local(&self, w: usize) -> std::sync::MutexGuard<'_, PrioQueues> {
        match self.locals[w].try_lock() {
            Ok(g) => g,
            Err(_) => {
                self.counters.queue_contended.inc();
                self.locals[w].lock().unwrap()
            }
        }
    }
}

impl Policy for LocalPriority {
    fn push(&self, task: Task, hint: Option<usize>) {
        match hint {
            Some(w) => {
                let mut g = self.lock_local(w);
                push_prio(&mut g, task);
                self.counters.queue_hwm.max(len_prio(&g) as u64);
            }
            None => {
                // Off-pool producers round-robin across local queues so a
                // burst from the parcel port spreads without stealing.
                let w = self.rr.fetch_add(1, Ordering::Relaxed) % self.locals.len();
                let mut g = self.lock_local(w);
                push_prio(&mut g, task);
                self.counters.queue_hwm.max(len_prio(&g) as u64);
            }
        }
        let _ = &self.injector; // injector reserved for explicit broadcast use
    }

    fn pop(&self, w: usize) -> Option<Task> {
        // 1. Own queues, highest priority first.
        if let Some(t) = pop_prio(&mut self.lock_local(w)) {
            return Some(t);
        }
        // 2. Injector.
        if let Some(t) = pop_prio(&mut self.injector.lock().unwrap()) {
            return Some(t);
        }
        // 3. Steal: scan victims from w+1, take their *oldest* task
        //    (back of the FIFO order we pop from the front of) to move the
        //    largest expected remaining work and reduce steal frequency.
        let n = self.locals.len();
        for off in 1..n {
            let v = (w + off) % n;
            if let Ok(mut g) = self.locals[v].try_lock() {
                for q in g.iter_mut() {
                    if let Some(t) = q.pop_back() {
                        self.counters.steals.inc();
                        return Some(t);
                    }
                }
            }
        }
        None
    }

    fn approx_len(&self) -> usize {
        let mut n = len_prio(&self.injector.lock().unwrap());
        for l in &self.locals {
            if let Ok(g) = l.try_lock() {
                n += len_prio(&g);
            }
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(prio: Priority) -> Task {
        Task { prio, f: Box::new(|_| {}) }
    }

    #[test]
    fn global_queue_fifo_within_priority() {
        let c = Arc::new(Counters::default());
        let q = GlobalQueue::new(c);
        let seen = Arc::new(Mutex::new(Vec::new()));
        for i in 0..3 {
            let seen = seen.clone();
            q.push(
                Task { prio: Priority::Normal, f: Box::new(move |_| seen.lock().unwrap().push(i)) },
                None,
            );
        }
        assert_eq!(q.approx_len(), 3);
        // Pop order must match push order (FIFO); we can't call f without a
        // Spawner here, so check by draining lengths only.
        assert!(q.pop(0).is_some());
        assert_eq!(q.approx_len(), 2);
    }

    #[test]
    fn global_queue_priority_order() {
        let q = GlobalQueue::new(Arc::new(Counters::default()));
        q.push(task(Priority::Low), None);
        q.push(task(Priority::High), None);
        q.push(task(Priority::Normal), None);
        assert_eq!(q.pop(0).unwrap().prio, Priority::High);
        assert_eq!(q.pop(0).unwrap().prio, Priority::Normal);
        assert_eq!(q.pop(0).unwrap().prio, Priority::Low);
        assert!(q.pop(0).is_none());
    }

    #[test]
    fn local_priority_hint_lands_on_that_worker() {
        let q = LocalPriority::new(4, Arc::new(Counters::default()));
        q.push(task(Priority::Normal), Some(2));
        // Worker 2 gets it without stealing.
        let c_before = q.counters.steals.get();
        assert!(q.pop(2).is_some());
        assert_eq!(q.counters.steals.get(), c_before);
    }

    #[test]
    fn local_priority_steal_from_any_victim() {
        let q = LocalPriority::new(4, Arc::new(Counters::default()));
        q.push(task(Priority::Normal), Some(0));
        // Worker 3 finds nothing local, must steal from 0.
        assert!(q.pop(3).is_some());
        assert_eq!(q.counters.steals.get(), 1);
        assert!(q.pop(3).is_none());
    }

    #[test]
    fn local_priority_offpool_pushes_spread_round_robin() {
        let q = LocalPriority::new(4, Arc::new(Counters::default()));
        for _ in 0..8 {
            q.push(task(Priority::Normal), None);
        }
        // Every worker should find at least one task locally (no steals).
        for w in 0..4 {
            assert!(q.pop(w).is_some(), "worker {w} empty");
        }
        assert_eq!(q.counters.steals.get(), 0);
    }

    #[test]
    fn hwm_tracks_longest_queue() {
        let c = Arc::new(Counters::default());
        let q = GlobalQueue::new(c.clone());
        for _ in 0..10 {
            q.push(task(Priority::Normal), None);
        }
        assert_eq!(c.queue_hwm.get(), 10);
    }
}
