//! Simulated interconnect between localities.
//!
//! The paper's HPX prototype moved parcels over TCP/IP between cluster
//! nodes. This runtime hosts all localities in one process (DESIGN.md §3)
//! and models the wire instead: each parcel is *serialized to bytes* (so
//! the full encode/decode path runs), then delivered to the destination
//! locality's parcel port after a modeled delay
//!
//! `latency = base_latency + bytes / bandwidth`
//!
//! by a dedicated delivery thread draining a deadline-ordered heap. A
//! zero-cost [`NetModel::instant`] configuration is available for unit
//! tests; experiments use [`NetModel::cluster_like`] (µs-scale base
//! latency approximating the paper's gigabit-Ethernet era testbed).
//! Failure injection: a drop predicate can be installed to test parcel
//! loss handling in integration tests.
//!
//! Ports are a *lifecycle*, not a boot-time constant: elastic membership
//! (DESIGN.md §8) detaches a retiring locality's port after draining its
//! in-flight parcels ([`SimNet::drain_to`] + [`SimNet::detach_port`]) and
//! re-attaches on boot. A parcel that still reaches a detached port —
//! e.g. a sender that resolved a stale placement in the instants around
//! retirement — is **bounced** to the anchor locality 0 (whose action
//! manager hop-forwards it via a fresh AGAS resolve) instead of being
//! dropped, so retirement can never lose a dataflow input.
//!
//! The per-parcel `base_latency` term is the lever behind the AMR
//! driver's ghost batching (DESIGN.md §7): `n` fragments coalesced into
//! one parcel pay the base latency once and the bandwidth term for the
//! same payload bytes, so BENCH_3's batched rows send strictly fewer
//! parcels for identical physics.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::error::{PxError, PxResult};
use super::gid::LocalityId;
use super::parcel::Parcel;

/// Latency/bandwidth model for one runtime's interconnect.
#[derive(Debug, Clone, Copy)]
pub struct NetModel {
    /// Fixed per-parcel latency.
    pub base_latency: Duration,
    /// Payload cost in bytes/second (`u64::MAX`-like values ≈ free).
    pub bandwidth_bps: u64,
}

impl NetModel {
    /// No modeled delay (unit tests).
    pub fn instant() -> NetModel {
        NetModel { base_latency: Duration::ZERO, bandwidth_bps: u64::MAX }
    }

    /// Gigabit-Ethernet-era cluster: ~50 µs base latency, 1 Gb/s payload.
    pub fn cluster_like() -> NetModel {
        NetModel { base_latency: Duration::from_micros(50), bandwidth_bps: 125_000_000 }
    }

    /// Delivery delay for a parcel of `bytes` length.
    pub fn delay(&self, bytes: usize) -> Duration {
        if self.bandwidth_bps == u64::MAX {
            return self.base_latency;
        }
        self.base_latency + Duration::from_nanos((bytes as u64).saturating_mul(1_000_000_000) / self.bandwidth_bps)
    }
}

/// A timed in-flight message.
struct InFlight {
    deliver_at: Instant,
    seq: u64, // FIFO tie-break for equal deadlines
    dest: LocalityId,
    bytes: Vec<u8>,
}

impl PartialEq for InFlight {
    fn eq(&self, o: &Self) -> bool {
        self.deliver_at == o.deliver_at && self.seq == o.seq
    }
}
impl Eq for InFlight {}
impl PartialOrd for InFlight {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for InFlight {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        (self.deliver_at, self.seq).cmp(&(o.deliver_at, o.seq))
    }
}

type PortFn = Box<dyn Fn(Vec<u8>) + Send + Sync>;

/// splitmix64 stream for the seeded loss model — reproducible chaos
/// without pulling a `rand` dependency into the offline build.
struct LossState {
    state: u64,
    p: f64,
}

impl LossState {
    /// Advance the stream; true when the next parcel should be lost.
    fn lose_next(&mut self) -> bool {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        ((z >> 11) as f64 / (1u64 << 53) as f64) < self.p
    }
}

struct NetShared {
    model: NetModel,
    heap: Mutex<BinaryHeap<Reverse<InFlight>>>,
    cv: Condvar,
    heap_lock_for_cv: Mutex<()>,
    ports: Mutex<Vec<Option<Arc<PortFn>>>>,
    in_flight: AtomicU64,
    seq: AtomicU64,
    shutdown: AtomicBool,
    /// Failure injection: parcels for which this returns true are dropped.
    drop_filter: Mutex<Option<Box<dyn Fn(&Parcel) -> bool + Send + Sync>>>,
    /// Seeded probabilistic wire loss (chaos runs); independent of and in
    /// addition to the predicate filter above.
    loss: Mutex<Option<LossState>>,
    dropped: AtomicU64,
    /// Per-destination quarantine (crash injection). A parcel due for a
    /// quarantined locality is *captured* — bytes retained in
    /// `dead_queue` for recovery replay — instead of bounced to the
    /// anchor: bouncing during the recovery window would hop-forward
    /// against a stale AGAS view that still names the dead home.
    quarantined: Mutex<Vec<bool>>,
    /// Captured `(dest, bytes)` of parcels that hit a quarantined port,
    /// drained by [`SimNet::take_dead_letters`] for replay.
    dead_queue: Mutex<Vec<(LocalityId, Vec<u8>)>>,
    /// Parcels that arrived at a detached port and were re-delivered to
    /// the anchor locality's port (elastic-retirement stragglers).
    bounced: AtomicU64,
    /// Parcels that arrived at a detached port with no anchor to bounce
    /// to (only possible if locality 0's port is missing — a protocol
    /// violation, since the anchor never retires).
    dead_letters: AtomicU64,
}

/// The simulated network fabric connecting all localities.
pub struct SimNet {
    shared: Arc<NetShared>,
    delivery: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl SimNet {
    /// Fabric for `n_localities` endpoints under `model`.
    pub fn new(n_localities: usize, model: NetModel) -> Arc<SimNet> {
        let shared = Arc::new(NetShared {
            model,
            heap: Mutex::new(BinaryHeap::new()),
            cv: Condvar::new(),
            heap_lock_for_cv: Mutex::new(()),
            ports: Mutex::new((0..n_localities).map(|_| None).collect()),
            in_flight: AtomicU64::new(0),
            seq: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            drop_filter: Mutex::new(None),
            loss: Mutex::new(None),
            dropped: AtomicU64::new(0),
            quarantined: Mutex::new(vec![false; n_localities]),
            dead_queue: Mutex::new(Vec::new()),
            bounced: AtomicU64::new(0),
            dead_letters: AtomicU64::new(0),
        });
        let net = Arc::new(SimNet { shared: shared.clone(), delivery: Mutex::new(None) });
        let h = std::thread::Builder::new()
            .name("px-net-delivery".into())
            .spawn(move || delivery_loop(shared))
            .expect("spawn net delivery");
        *net.delivery.lock().unwrap() = Some(h);
        net
    }

    /// Attach locality `l`'s parcel port — at runtime boot and again when
    /// an elastic membership change re-boots a previously retired
    /// locality. Attaching over a live port is a protocol error.
    pub fn attach_port<F: Fn(Vec<u8>) + Send + Sync + 'static>(&self, l: LocalityId, port: F) {
        let mut ports = self.shared.ports.lock().unwrap();
        assert!(ports[l as usize].is_none(), "port {l} already attached");
        ports[l as usize] = Some(Arc::new(Box::new(port)));
        // A reboot revives a previously killed slot: lift the quarantine
        // so deliveries flow directly again.
        self.shared.quarantined.lock().unwrap()[l as usize] = false;
    }

    /// Detach locality `l`'s parcel port (elastic retirement). Returns
    /// whether a port was attached. Callers should [`SimNet::drain_to`]
    /// first; anything that still arrives afterwards is bounced to the
    /// anchor locality's port rather than lost.
    pub fn detach_port(&self, l: LocalityId) -> bool {
        self.shared.ports.lock().unwrap()[l as usize].take().is_some()
    }

    /// Whether locality `l` currently has a port attached.
    pub fn has_port(&self, l: LocalityId) -> bool {
        self.shared.ports.lock().unwrap()[l as usize].is_some()
    }

    /// Crash injection: force-detach locality `l`'s port with **no
    /// drain** and quarantine the slot. Unlike [`SimNet::detach_port`]
    /// (graceful retirement), parcels already on the wire for `l` are not
    /// bounced to the anchor — they are captured as dead letters for the
    /// recovery subsystem to replay once AGAS has been repaired
    /// ([`SimNet::take_dead_letters`]). Returns whether a port was live.
    pub fn kill_port(&self, l: LocalityId) -> bool {
        // Quarantine before detaching so no delivery slips through the
        // `None`-port window into the anchor-bounce path.
        self.shared.quarantined.lock().unwrap()[l as usize] = true;
        self.shared.ports.lock().unwrap()[l as usize].take().is_some()
    }

    /// Whether locality `l` is quarantined (killed and not yet re-booted).
    pub fn is_quarantined(&self, l: LocalityId) -> bool {
        self.shared.quarantined.lock().unwrap()[l as usize]
    }

    /// Drain the captured dead letters for replay. Each entry is the
    /// original destination and the serialized parcel bytes, in delivery
    /// order. The [`SimNet::dead_letters`] tally is decremented by the
    /// number drained, so a successful replay returns it to 0.
    pub fn take_dead_letters(&self) -> Vec<(LocalityId, Vec<u8>)> {
        let out = std::mem::take(&mut *self.shared.dead_queue.lock().unwrap());
        self.shared.dead_letters.fetch_sub(out.len() as u64, Ordering::SeqCst);
        out
    }

    /// Number of endpoint slots this fabric was built with (the roster
    /// capacity — membership within it is dynamic).
    pub fn capacity(&self) -> usize {
        self.shared.ports.lock().unwrap().len()
    }

    /// Parcels still queued in the wire heap for destination `l`. A
    /// parcel already popped by the delivery thread is not counted — the
    /// bounce path covers that residual window.
    pub fn in_flight_to(&self, l: LocalityId) -> u64 {
        let heap = self.shared.heap.lock().unwrap();
        heap.iter().filter(|Reverse(m)| m.dest == l).count() as u64
    }

    /// Block until no parcel destined for `l` remains in the wire heap
    /// (the retirement drain), or fail after `timeout`.
    pub fn drain_to(&self, l: LocalityId, timeout: Duration) -> PxResult<()> {
        let deadline = Instant::now() + timeout;
        loop {
            if self.in_flight_to(l) == 0 {
                return Ok(());
            }
            if Instant::now() > deadline {
                return Err(PxError::TaskFailed(format!(
                    "drain of locality {l} timed out with {} parcel(s) in flight",
                    self.in_flight_to(l)
                )));
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    /// Install a failure-injection predicate (tests). Parcels matching the
    /// predicate vanish in flight and bump [`SimNet::dropped`].
    pub fn set_drop_filter<F: Fn(&Parcel) -> bool + Send + Sync + 'static>(&self, f: F) {
        *self.shared.drop_filter.lock().unwrap() = Some(Box::new(f));
    }

    /// Install a seeded probabilistic drop filter: each send is lost with
    /// probability `p`, decided by a splitmix64 stream started at `seed`,
    /// so a chaos run replays bit-for-bit from the CLI (`--loss-rate`).
    /// Lost parcels bump [`SimNet::dropped`] exactly like the predicate
    /// filter — this injects *unrecoverable* wire loss, which the AMR
    /// driver detects and surfaces as an error rather than a hang.
    /// `p <= 0` clears the model.
    pub fn set_loss_rate(&self, seed: u64, p: f64) {
        *self.shared.loss.lock().unwrap() =
            if p <= 0.0 { None } else { Some(LossState { state: seed, p }) };
    }

    /// Send a parcel: serialize, apply the wire model, schedule delivery.
    pub fn send(&self, dest: LocalityId, parcel: &Parcel) -> PxResult<usize> {
        if self.shared.shutdown.load(Ordering::SeqCst) {
            return Err(PxError::ShuttingDown);
        }
        if let Some(f) = &*self.shared.drop_filter.lock().unwrap() {
            if f(parcel) {
                self.shared.dropped.fetch_add(1, Ordering::SeqCst);
                return Ok(0);
            }
        }
        if let Some(ls) = &mut *self.shared.loss.lock().unwrap() {
            if ls.lose_next() {
                self.shared.dropped.fetch_add(1, Ordering::SeqCst);
                return Ok(0);
            }
        }
        let bytes = parcel.encode();
        let n = bytes.len();
        let deliver_at = Instant::now() + self.shared.model.delay(n);
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        {
            let mut heap = self.shared.heap.lock().unwrap();
            heap.push(Reverse(InFlight {
                deliver_at,
                seq: self.shared.seq.fetch_add(1, Ordering::Relaxed),
                dest,
                bytes,
            }));
        }
        let _g = self.shared.heap_lock_for_cv.lock().unwrap();
        self.shared.cv.notify_one();
        Ok(n)
    }

    /// Parcels accepted but not yet delivered to a port.
    pub fn in_flight(&self) -> u64 {
        self.shared.in_flight.load(Ordering::SeqCst)
    }

    /// Parcels destroyed by the failure-injection filter.
    pub fn dropped(&self) -> u64 {
        self.shared.dropped.load(Ordering::SeqCst)
    }

    /// Parcels that hit a detached port and were re-delivered via the
    /// anchor locality (elastic-retirement stragglers; each one is then
    /// hop-forwarded to its object's current home).
    pub fn bounced(&self) -> u64 {
        self.shared.bounced.load(Ordering::SeqCst)
    }

    /// Parcels currently held as dead letters: quarantined-port captures
    /// awaiting replay, plus parcels lost at a detached port with no
    /// anchor to bounce to (only possible if locality 0's port is
    /// missing). Returns to 0 after a successful recovery replay; stays 0
    /// outright under the graceful elastic protocol.
    pub fn dead_letters(&self) -> u64 {
        self.shared.dead_letters.load(Ordering::SeqCst)
    }

    /// Stop the delivery thread; undelivered parcels are discarded.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        {
            let _g = self.shared.heap_lock_for_cv.lock().unwrap();
            self.shared.cv.notify_all();
        }
        if let Some(h) = self.delivery.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for SimNet {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn delivery_loop(sh: Arc<NetShared>) {
    loop {
        if sh.shutdown.load(Ordering::SeqCst) {
            return;
        }
        // Pop everything due; compute sleep until the next deadline.
        let mut due: Vec<InFlight> = Vec::new();
        let sleep_for: Option<Duration> = {
            let mut heap = sh.heap.lock().unwrap();
            let now = Instant::now();
            while let Some(Reverse(top)) = heap.peek() {
                if top.deliver_at <= now {
                    due.push(heap.pop().unwrap().0);
                } else {
                    break;
                }
            }
            heap.peek().map(|Reverse(t)| t.deliver_at.saturating_duration_since(now))
        };
        for m in due {
            if sh.quarantined.lock().unwrap()[m.dest as usize] {
                // Crash quarantine: hold the bytes for recovery replay.
                sh.dead_letters.fetch_add(1, Ordering::SeqCst);
                sh.dead_queue.lock().unwrap().push((m.dest, m.bytes));
                sh.in_flight.fetch_sub(1, Ordering::SeqCst);
                continue;
            }
            let (port, anchor) = {
                let ports = sh.ports.lock().unwrap();
                (ports[m.dest as usize].clone(), ports.first().and_then(|p| p.clone()))
            };
            match port {
                Some(p) => p(m.bytes),
                None => match anchor {
                    // Destination retired between send and delivery:
                    // bounce through the anchor locality, whose action
                    // manager hop-forwards after a fresh AGAS resolve.
                    Some(p) if m.dest != 0 => {
                        sh.bounced.fetch_add(1, Ordering::SeqCst);
                        p(m.bytes);
                    }
                    _ => {
                        sh.dead_letters.fetch_add(1, Ordering::SeqCst);
                    }
                },
            }
            sh.in_flight.fetch_sub(1, Ordering::SeqCst);
        }
        let g = sh.heap_lock_for_cv.lock().unwrap();
        let wait = sleep_for.unwrap_or(Duration::from_millis(2));
        let _ = sh.cv.wait_timeout(g, wait.min(Duration::from_millis(2))).unwrap();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::px::gid::{Gid, GidKind};
    use std::sync::mpsc;

    fn parcel(n_args: usize) -> Parcel {
        Parcel::new(Gid::new(0, GidKind::Block, 1), 7, vec![0xAB; n_args], 0)
    }

    #[test]
    fn delivers_to_attached_port() {
        let net = SimNet::new(2, NetModel::instant());
        let (tx, rx) = mpsc::channel();
        net.attach_port(1, move |bytes| tx.send(bytes).unwrap());
        let p = parcel(8);
        net.send(1, &p).unwrap();
        let bytes = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(Parcel::decode(&bytes).unwrap(), p);
        // in_flight decrements just *after* the port callback (so that
        // quiescence never races ahead of task creation) — poll briefly.
        let deadline = Instant::now() + Duration::from_secs(2);
        while net.in_flight() != 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(net.in_flight(), 0);
    }

    #[test]
    fn latency_model_orders_deliveries_by_deadline() {
        // Large payload on a slow link must arrive after a later-sent
        // small payload.
        let net = SimNet::new(1, NetModel { base_latency: Duration::ZERO, bandwidth_bps: 1_000_000 });
        let (tx, rx) = mpsc::channel();
        net.attach_port(0, move |bytes| tx.send(bytes.len()).unwrap());
        net.send(0, &parcel(50_000)).unwrap(); // ~50ms wire time
        std::thread::sleep(Duration::from_millis(2));
        net.send(0, &parcel(10)).unwrap(); // ~10us wire time
        let first = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        let second = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(first < second, "small parcel must overtake large: {first} vs {second}");
    }

    #[test]
    fn base_latency_is_respected() {
        let net = SimNet::new(1, NetModel { base_latency: Duration::from_millis(20), bandwidth_bps: u64::MAX });
        let (tx, rx) = mpsc::channel();
        net.attach_port(0, move |_| tx.send(Instant::now()).unwrap());
        let sent = Instant::now();
        net.send(0, &parcel(1)).unwrap();
        let arrived = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(arrived - sent >= Duration::from_millis(19), "arrived too early: {:?}", arrived - sent);
    }

    #[test]
    fn drop_filter_discards_matching_parcels() {
        let net = SimNet::new(1, NetModel::instant());
        let (tx, rx) = mpsc::channel();
        net.attach_port(0, move |b| tx.send(b).unwrap());
        net.set_drop_filter(|p| p.action == 13);
        let doomed = Parcel::new(Gid::new(0, GidKind::Block, 1), 13, vec![], 0);
        net.send(0, &doomed).unwrap();
        let ok = parcel(4);
        net.send(0, &ok).unwrap();
        let got = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(Parcel::decode(&got).unwrap().action, 7);
        assert_eq!(net.dropped(), 1);
        assert!(rx.try_recv().is_err());
    }

    #[test]
    fn detached_port_bounces_to_anchor_and_reattach_restores() {
        let net = SimNet::new(3, NetModel::instant());
        let (tx0, rx0) = mpsc::channel();
        net.attach_port(0, move |b| tx0.send(b).unwrap());
        let (tx2, rx2) = mpsc::channel();
        net.attach_port(2, move |b| tx2.send(b).unwrap());
        // Direct delivery while attached.
        net.send(2, &parcel(4)).unwrap();
        rx2.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(net.bounced(), 0);
        // Retire 2: drain then detach; a straggler bounces to the anchor.
        net.drain_to(2, Duration::from_secs(2)).unwrap();
        assert!(net.detach_port(2));
        assert!(!net.has_port(2));
        net.send(2, &parcel(4)).unwrap();
        let bytes = rx0.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(Parcel::decode(&bytes).unwrap(), parcel(4));
        assert_eq!(net.bounced(), 1);
        assert_eq!(net.dead_letters(), 0);
        // Re-boot: attach a fresh port; direct delivery resumes.
        let (tx2b, rx2b) = mpsc::channel();
        net.attach_port(2, move |b| tx2b.send(b).unwrap());
        net.send(2, &parcel(8)).unwrap();
        rx2b.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(net.bounced(), 1, "re-attached port must receive directly");
    }

    #[test]
    fn drain_to_waits_for_destination_parcels_only() {
        let net = SimNet::new(2, NetModel { base_latency: Duration::from_millis(30), bandwidth_bps: u64::MAX });
        net.attach_port(0, |_| {});
        net.attach_port(1, |_| {});
        net.send(1, &parcel(4)).unwrap();
        assert_eq!(net.in_flight_to(1), 1);
        assert_eq!(net.in_flight_to(0), 0);
        net.drain_to(0, Duration::from_millis(1)).unwrap(); // nothing for 0
        net.drain_to(1, Duration::from_secs(2)).unwrap();
        assert_eq!(net.in_flight_to(1), 0);
    }

    #[test]
    fn detached_anchor_dead_letters() {
        let net = SimNet::new(1, NetModel::instant());
        // No port ever attached at 0: nothing to bounce to.
        net.send(0, &parcel(2)).unwrap();
        let deadline = Instant::now() + Duration::from_secs(2);
        while net.dead_letters() == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(net.dead_letters(), 1);
        assert_eq!(net.bounced(), 0);
    }

    #[test]
    fn kill_port_quarantines_and_captures_dead_letters() {
        let net = SimNet::new(3, NetModel::instant());
        let (tx0, rx0) = mpsc::channel();
        net.attach_port(0, move |b| tx0.send(b).unwrap());
        net.attach_port(2, |_| {});
        // Hard kill: no drain, no bounce — arrivals are captured.
        assert!(net.kill_port(2));
        assert!(net.is_quarantined(2));
        assert!(!net.has_port(2));
        net.send(2, &parcel(4)).unwrap();
        let deadline = Instant::now() + Duration::from_secs(2);
        while net.dead_letters() == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(net.dead_letters(), 1);
        assert_eq!(net.bounced(), 0, "quarantined arrivals must not bounce");
        assert!(rx0.try_recv().is_err(), "anchor must not see quarantined parcels");
        // Replay drain: bytes come back intact, tally returns to 0.
        let dead = net.take_dead_letters();
        assert_eq!(dead.len(), 1);
        assert_eq!(dead[0].0, 2);
        assert_eq!(Parcel::decode(&dead[0].1).unwrap(), parcel(4));
        assert_eq!(net.dead_letters(), 0);
        assert!(net.take_dead_letters().is_empty());
    }

    #[test]
    fn reattach_after_kill_lifts_quarantine() {
        let net = SimNet::new(2, NetModel::instant());
        net.attach_port(1, |_| {});
        assert!(net.kill_port(1));
        let (tx, rx) = mpsc::channel();
        net.attach_port(1, move |b| tx.send(b).unwrap());
        assert!(!net.is_quarantined(1));
        net.send(1, &parcel(4)).unwrap();
        rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(net.dead_letters(), 0);
    }

    #[test]
    fn loss_rate_is_seed_deterministic() {
        let run = |seed: u64| {
            let net = SimNet::new(1, NetModel::instant());
            net.attach_port(0, |_| {});
            net.set_loss_rate(seed, 0.3);
            for _ in 0..200 {
                net.send(0, &parcel(4)).unwrap();
            }
            net.dropped()
        };
        let a = run(42);
        let b = run(42);
        let c = run(43);
        assert_eq!(a, b, "same seed must lose the same parcels");
        assert!(a > 0 && a < 200, "p=0.3 over 200 sends should lose some, not all: {a}");
        // A different seed exercises a different stream (overwhelmingly).
        assert!(a != c || a > 0);
        // p <= 0 clears the model.
        let net = SimNet::new(1, NetModel::instant());
        net.attach_port(0, |_| {});
        net.set_loss_rate(7, 0.9);
        net.set_loss_rate(7, 0.0);
        for _ in 0..50 {
            net.send(0, &parcel(2)).unwrap();
        }
        assert_eq!(net.dropped(), 0);
    }

    #[test]
    fn send_after_shutdown_errors() {
        let net = SimNet::new(1, NetModel::instant());
        net.shutdown();
        assert!(matches!(net.send(0, &parcel(1)), Err(PxError::ShuttingDown)));
    }

    #[test]
    fn in_flight_counts_pending() {
        let net = SimNet::new(1, NetModel { base_latency: Duration::from_millis(50), bandwidth_bps: u64::MAX });
        net.attach_port(0, |_| {});
        net.send(0, &parcel(1)).unwrap();
        assert_eq!(net.in_flight(), 1);
        let deadline = Instant::now() + Duration::from_secs(2);
        while net.in_flight() != 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(net.in_flight(), 0);
    }
}
