//! Failure detection for unplanned locality death (DESIGN.md §9).
//!
//! PR 4's elastic membership assumed *cooperative* departure: a retiring
//! locality drains its blocks and its wire before its port detaches. A
//! production machine gives no such notice, so this module adds the
//! ParalleX analogue of a cluster membership service:
//!
//! * [`HeartbeatBoard`] — one monotone beat slot per roster locality.
//!   Each live member stamps its slot; a crash is *modeled* by halting
//!   the member's beat (plus [`crate::px::SimNet::kill_port`] on the
//!   wire side).
//! * [`Heartbeater`] — the in-process stand-in for every member's beat
//!   loop: one thread stamps all slots still marked beating, so halting
//!   a slot is exactly "that machine stopped".
//! * [`FailureDetector`] — the anchor-side monitor. Every poll interval
//!   it compares each watched slot against the last value it saw; a
//!   slot that fails to advance for `k_misses` consecutive polls is
//!   declared dead and the caller's `on_death` hook runs (the driver
//!   hooks recovery — forced retire, checkpoint replay, dead-letter
//!   replay — into it).
//!
//! The anchor (locality 0) is never declared dead: it is the bounce and
//! recovery root, and killing it is rejected up front by the runtime
//! (`Membership::check_retirable`) rather than detected here.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::counters::Counters;
use super::gid::LocalityId;

/// Per-locality monotone heartbeat slots shared by members and monitor.
pub struct HeartbeatBoard {
    beats: Vec<AtomicU64>,
    /// Member still stamping its beat. The crash switch flips this off —
    /// beats stop exactly like a machine losing power.
    beating: Vec<AtomicBool>,
    /// Failure detector monitors this slot. Graceful retirement (and a
    /// declared death) unwatch; a slot can be halted but still watched —
    /// that is precisely the crash the detector exists to catch.
    watched: Vec<AtomicBool>,
}

impl HeartbeatBoard {
    /// Board for a roster of `capacity` localities; no slot enrolled.
    pub fn new(capacity: usize) -> Arc<HeartbeatBoard> {
        Arc::new(HeartbeatBoard {
            beats: (0..capacity).map(|_| AtomicU64::new(0)).collect(),
            beating: (0..capacity).map(|_| AtomicBool::new(false)).collect(),
            watched: (0..capacity).map(|_| AtomicBool::new(false)).collect(),
        })
    }

    /// Roster capacity.
    pub fn capacity(&self) -> usize {
        self.beats.len()
    }

    /// Enroll `l` in the protocol: it beats and the detector watches it.
    pub fn enroll(&self, l: LocalityId) {
        self.beating[l as usize].store(true, Ordering::SeqCst);
        self.watched[l as usize].store(true, Ordering::SeqCst);
    }

    /// Crash switch: `l` stops beating but stays watched — the detector
    /// will notice after `k_misses` polls.
    pub fn halt(&self, l: LocalityId) {
        self.beating[l as usize].store(false, Ordering::SeqCst);
    }

    /// Graceful exit (or post-mortem): stop monitoring `l` entirely.
    pub fn unwatch(&self, l: LocalityId) {
        self.beating[l as usize].store(false, Ordering::SeqCst);
        self.watched[l as usize].store(false, Ordering::SeqCst);
    }

    /// Stamp one beat for `l` (members call this; monotone).
    pub fn beat(&self, l: LocalityId) {
        self.beats[l as usize].fetch_add(1, Ordering::SeqCst);
    }

    /// Current beat value for `l`.
    pub fn beat_of(&self, l: LocalityId) -> u64 {
        self.beats[l as usize].load(Ordering::SeqCst)
    }

    /// Whether `l` is still stamping beats.
    pub fn is_beating(&self, l: LocalityId) -> bool {
        self.beating[l as usize].load(Ordering::SeqCst)
    }

    /// Whether the detector is monitoring `l`.
    pub fn is_watched(&self, l: LocalityId) -> bool {
        self.watched[l as usize].load(Ordering::SeqCst)
    }
}

/// One thread stamping beats for every slot still marked beating — the
/// in-process model of each member's own beat loop. Halting a slot on
/// the board stops its beat without touching the others.
pub struct Heartbeater {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Heartbeater {
    /// Stamp all beating slots every `every`.
    pub fn spawn(board: Arc<HeartbeatBoard>, every: Duration) -> Heartbeater {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let handle = std::thread::Builder::new()
            .name("px-heartbeater".into())
            .spawn(move || {
                while !flag.load(Ordering::SeqCst) {
                    for l in 0..board.capacity() {
                        if board.is_beating(l as LocalityId) {
                            board.beat(l as LocalityId);
                        }
                    }
                    std::thread::sleep(every);
                }
            })
            .expect("spawn heartbeater");
        Heartbeater { stop, handle: Some(handle) }
    }

    /// Stop stamping and join.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Heartbeater {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// A death the detector declared.
#[derive(Debug, Clone)]
pub struct DeathNotice {
    /// The locality declared dead.
    pub locality: LocalityId,
    /// Consecutive missed polls that triggered the declaration.
    pub missed: u64,
    /// Wall time from the first missed poll to the declaration — the
    /// detection component of recovery latency.
    pub detection_latency: Duration,
}

/// What the detector saw over its lifetime.
#[derive(Debug, Clone, Default)]
pub struct DetectorStats {
    /// Deaths declared, in declaration order.
    pub deaths: Vec<DeathNotice>,
    /// Total missed heartbeat deadlines across all watched slots.
    pub heartbeats_missed: u64,
}

/// Anchor-side heartbeat monitor. Polls the board every `every`; a
/// watched non-anchor slot whose beat fails to advance for `k_misses`
/// consecutive polls is declared dead: the slot is unwatched, the
/// `heartbeats_missed` counter is charged, and `on_death` runs on the
/// detector thread (the driver's recovery hook).
pub struct FailureDetector {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<DetectorStats>>,
}

impl FailureDetector {
    /// Spawn the monitor. `counters` is the anchor's set — every missed
    /// deadline bumps `heartbeats_missed` so detector health shows up in
    /// `counters_total` and bench artifacts.
    pub fn spawn<F>(
        board: Arc<HeartbeatBoard>,
        every: Duration,
        k_misses: u64,
        counters: Arc<Counters>,
        mut on_death: F,
    ) -> FailureDetector
    where
        F: FnMut(LocalityId) + Send + 'static,
    {
        assert!(k_misses > 0, "failure detector needs at least one missed beat");
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let handle = std::thread::Builder::new()
            .name("px-failure-detector".into())
            .spawn(move || {
                let cap = board.capacity();
                let mut last_seen = vec![0u64; cap];
                let mut misses = vec![0u64; cap];
                let mut first_miss: Vec<Option<Instant>> = vec![None; cap];
                let mut stats = DetectorStats::default();
                while !flag.load(Ordering::SeqCst) {
                    std::thread::sleep(every);
                    // The anchor (slot 0) is never declared dead.
                    for l in 1..cap {
                        if !board.is_watched(l as LocalityId) {
                            misses[l] = 0;
                            first_miss[l] = None;
                            continue;
                        }
                        let b = board.beat_of(l as LocalityId);
                        if b != last_seen[l] {
                            last_seen[l] = b;
                            misses[l] = 0;
                            first_miss[l] = None;
                            continue;
                        }
                        misses[l] += 1;
                        stats.heartbeats_missed += 1;
                        counters.heartbeats_missed.inc();
                        let since = *first_miss[l].get_or_insert_with(Instant::now);
                        if misses[l] >= k_misses {
                            board.unwatch(l as LocalityId);
                            stats.deaths.push(DeathNotice {
                                locality: l as LocalityId,
                                missed: misses[l],
                                detection_latency: since.elapsed(),
                            });
                            on_death(l as LocalityId);
                        }
                    }
                }
                stats
            })
            .expect("spawn failure detector");
        FailureDetector { stop, handle: Some(handle) }
    }

    /// Stop the monitor and collect its stats.
    pub fn stop(mut self) -> DetectorStats {
        self.stop.store(true, Ordering::SeqCst);
        match self.handle.take() {
            Some(h) => h.join().unwrap_or_default(),
            None => DetectorStats::default(),
        }
    }
}

impl Drop for FailureDetector {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    #[test]
    fn board_tracks_enroll_halt_unwatch() {
        let board = HeartbeatBoard::new(4);
        assert_eq!(board.capacity(), 4);
        board.enroll(2);
        assert!(board.is_beating(2) && board.is_watched(2));
        board.halt(2);
        assert!(!board.is_beating(2) && board.is_watched(2), "halted slot stays watched");
        board.unwatch(2);
        assert!(!board.is_watched(2));
        board.beat(1);
        board.beat(1);
        assert_eq!(board.beat_of(1), 2);
        assert_eq!(board.beat_of(0), 0);
    }

    #[test]
    fn detector_declares_death_after_k_missed_beats() {
        let board = HeartbeatBoard::new(4);
        for l in 1..4 {
            board.enroll(l);
        }
        let beater = Heartbeater::spawn(board.clone(), Duration::from_micros(200));
        let counters = Arc::new(Counters::default());
        let (tx, rx) = mpsc::channel();
        let detector = FailureDetector::spawn(
            board.clone(),
            Duration::from_millis(1),
            3,
            counters.clone(),
            move |l| tx.send(l).unwrap(),
        );
        // Let everyone beat a while: no deaths.
        std::thread::sleep(Duration::from_millis(20));
        assert!(rx.try_recv().is_err(), "beating members must not be declared dead");
        // Crash locality 2: beats stop, port-side kill is the net's job.
        board.halt(2);
        let dead = rx.recv_timeout(Duration::from_secs(5)).expect("death declared");
        assert_eq!(dead, 2);
        assert!(!board.is_watched(2), "declared-dead slot is unwatched");
        let stats = detector.stop();
        beater.stop();
        assert_eq!(stats.deaths.len(), 1);
        assert_eq!(stats.deaths[0].locality, 2);
        assert!(stats.deaths[0].missed >= 3);
        assert!(stats.heartbeats_missed >= 3);
        assert_eq!(counters.heartbeats_missed.get(), stats.heartbeats_missed);
    }

    #[test]
    fn gracefully_unwatched_slot_is_never_declared() {
        let board = HeartbeatBoard::new(3);
        board.enroll(1);
        board.enroll(2);
        let beater = Heartbeater::spawn(board.clone(), Duration::from_micros(200));
        let (tx, rx) = mpsc::channel();
        let detector = FailureDetector::spawn(
            board.clone(),
            Duration::from_micros(500),
            2,
            Arc::new(Counters::default()),
            move |l| tx.send(l).unwrap(),
        );
        // Graceful retirement: unwatch *then* stop beating.
        board.unwatch(1);
        std::thread::sleep(Duration::from_millis(25));
        assert!(rx.try_recv().is_err(), "graceful exit must not look like a crash");
        drop(detector);
        beater.stop();
    }

    #[test]
    fn anchor_is_never_declared_dead() {
        let board = HeartbeatBoard::new(2);
        board.enroll(0);
        board.enroll(1);
        let beater = Heartbeater::spawn(board.clone(), Duration::from_micros(200));
        let (tx, rx) = mpsc::channel();
        let detector = FailureDetector::spawn(
            board.clone(),
            Duration::from_micros(500),
            2,
            Arc::new(Counters::default()),
            move |l| tx.send(l).unwrap(),
        );
        board.halt(0); // even a silent anchor is not the detector's call
        std::thread::sleep(Duration::from_millis(25));
        assert!(rx.try_recv().is_err());
        drop(detector);
        beater.stop();
    }
}
