//! Failure detection for unplanned locality death (DESIGN.md §9).
//!
//! PR 4's elastic membership assumed *cooperative* departure: a retiring
//! locality drains its blocks and its wire before its port detaches. A
//! production machine gives no such notice, so this module adds the
//! ParalleX analogue of a cluster membership service:
//!
//! * [`HeartbeatBoard`] — one monotone beat slot per roster locality.
//!   Each live member stamps its slot; a crash is *modeled* by halting
//!   the member's beat (plus [`crate::px::SimNet::kill_port`] on the
//!   wire side).
//! * [`Heartbeater`] — the in-process stand-in for every member's beat
//!   loop: one thread stamps all slots still marked beating, so halting
//!   a slot is exactly "that machine stopped".
//! * [`FailureDetector`] — the anchor-side monitor. Every poll interval
//!   it compares each watched slot against the last value it saw; a
//!   slot that fails to advance for `k_misses` consecutive polls is
//!   declared dead and the caller's `on_death` hook runs (the driver
//!   hooks recovery — forced retire, checkpoint replay, dead-letter
//!   replay — into it).
//!
//! The anchor (locality 0) is never declared dead: it is the bounce and
//! recovery root, and killing it is rejected up front by the runtime
//! (`Membership::check_retirable`) rather than detected here.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::counters::Counters;
use super::gid::LocalityId;

/// Per-locality monotone heartbeat slots shared by members and monitor.
pub struct HeartbeatBoard {
    beats: Vec<AtomicU64>,
    /// Member still stamping its beat. The crash switch flips this off —
    /// beats stop exactly like a machine losing power.
    beating: Vec<AtomicBool>,
    /// Failure detector monitors this slot. Graceful retirement (and a
    /// declared death) unwatch; a slot can be halted but still watched —
    /// that is precisely the crash the detector exists to catch.
    watched: Vec<AtomicBool>,
}

impl HeartbeatBoard {
    /// Board for a roster of `capacity` localities; no slot enrolled.
    pub fn new(capacity: usize) -> Arc<HeartbeatBoard> {
        Arc::new(HeartbeatBoard {
            beats: (0..capacity).map(|_| AtomicU64::new(0)).collect(),
            beating: (0..capacity).map(|_| AtomicBool::new(false)).collect(),
            watched: (0..capacity).map(|_| AtomicBool::new(false)).collect(),
        })
    }

    /// Roster capacity.
    pub fn capacity(&self) -> usize {
        self.beats.len()
    }

    /// Enroll `l` in the protocol: it beats and the detector watches it.
    pub fn enroll(&self, l: LocalityId) {
        self.beating[l as usize].store(true, Ordering::SeqCst);
        self.watched[l as usize].store(true, Ordering::SeqCst);
    }

    /// Crash switch: `l` stops beating but stays watched — the detector
    /// will notice after `k_misses` polls.
    pub fn halt(&self, l: LocalityId) {
        self.beating[l as usize].store(false, Ordering::SeqCst);
    }

    /// Graceful exit (or post-mortem): stop monitoring `l` entirely.
    pub fn unwatch(&self, l: LocalityId) {
        self.beating[l as usize].store(false, Ordering::SeqCst);
        self.watched[l as usize].store(false, Ordering::SeqCst);
    }

    /// Stamp one beat for `l` (members call this; monotone).
    pub fn beat(&self, l: LocalityId) {
        self.beats[l as usize].fetch_add(1, Ordering::SeqCst);
    }

    /// Current beat value for `l`.
    pub fn beat_of(&self, l: LocalityId) -> u64 {
        self.beats[l as usize].load(Ordering::SeqCst)
    }

    /// Whether `l` is still stamping beats.
    pub fn is_beating(&self, l: LocalityId) -> bool {
        self.beating[l as usize].load(Ordering::SeqCst)
    }

    /// Whether the detector is monitoring `l`.
    pub fn is_watched(&self, l: LocalityId) -> bool {
        self.watched[l as usize].load(Ordering::SeqCst)
    }
}

/// One thread stamping beats for every slot still marked beating — the
/// in-process model of each member's own beat loop. Halting a slot on
/// the board stops its beat without touching the others.
pub struct Heartbeater {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Heartbeater {
    /// Stamp all beating slots every `every`.
    pub fn spawn(board: Arc<HeartbeatBoard>, every: Duration) -> Heartbeater {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let handle = std::thread::Builder::new()
            .name("px-heartbeater".into())
            .spawn(move || {
                while !flag.load(Ordering::SeqCst) {
                    for l in 0..board.capacity() {
                        if board.is_beating(l as LocalityId) {
                            board.beat(l as LocalityId);
                        }
                    }
                    std::thread::sleep(every);
                }
            })
            .expect("spawn heartbeater");
        Heartbeater { stop, handle: Some(handle) }
    }

    /// Stop stamping and join.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Heartbeater {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// A death the detector declared.
#[derive(Debug, Clone)]
pub struct DeathNotice {
    /// The locality declared dead.
    pub locality: LocalityId,
    /// Consecutive missed polls that triggered the declaration.
    pub missed: u64,
    /// Wall time from the first missed poll to the declaration — the
    /// detection component of recovery latency.
    pub detection_latency: Duration,
}

/// What the detector saw over its lifetime.
#[derive(Debug, Clone, Default)]
pub struct DetectorStats {
    /// Deaths declared, in declaration order.
    pub deaths: Vec<DeathNotice>,
    /// Total missed heartbeat deadlines across all watched slots.
    pub heartbeats_missed: u64,
}

/// Pure poll state of the failure detector, shared by the monitor thread
/// ([`FailureDetector`]) and the virtual-clock tests.
///
/// One [`poll`](DetectorCore::poll) is one monitor pass at a monotone
/// timestamp `now` — wall time for the threaded detector (its epoch's
/// `elapsed()`), virtual time under `sim::DetExecutor`. Factoring the
/// state out of the thread is what lets detection-latency tests assert
/// *exact* values instead of sleeping and hoping (DESIGN.md §11).
pub struct DetectorCore {
    k_misses: u64,
    last_seen: Vec<u64>,
    misses: Vec<u64>,
    first_miss: Vec<Option<Duration>>,
    stats: DetectorStats,
}

impl DetectorCore {
    /// Core for a board of `capacity` slots, declaring death after
    /// `k_misses` consecutive missed polls.
    pub fn new(capacity: usize, k_misses: u64) -> DetectorCore {
        assert!(k_misses > 0, "failure detector needs at least one missed beat");
        DetectorCore {
            k_misses,
            last_seen: vec![0; capacity],
            misses: vec![0; capacity],
            first_miss: vec![None; capacity],
            stats: DetectorStats::default(),
        }
    }

    /// One monitor pass at monotone instant `now`. Declared deaths are
    /// unwatched on the board, charged to `counters`/stats, and returned
    /// so the caller can run its recovery hook. The anchor (slot 0) is
    /// never declared dead.
    pub fn poll(
        &mut self,
        board: &HeartbeatBoard,
        now: Duration,
        counters: &Counters,
    ) -> Vec<DeathNotice> {
        let mut declared = Vec::new();
        for l in 1..board.capacity() {
            if !board.is_watched(l as LocalityId) {
                self.misses[l] = 0;
                self.first_miss[l] = None;
                continue;
            }
            let b = board.beat_of(l as LocalityId);
            if b != self.last_seen[l] {
                self.last_seen[l] = b;
                self.misses[l] = 0;
                self.first_miss[l] = None;
                continue;
            }
            self.misses[l] += 1;
            self.stats.heartbeats_missed += 1;
            counters.heartbeats_missed.inc();
            let since = *self.first_miss[l].get_or_insert(now);
            if self.misses[l] >= self.k_misses {
                board.unwatch(l as LocalityId);
                let notice = DeathNotice {
                    locality: l as LocalityId,
                    missed: self.misses[l],
                    detection_latency: now - since,
                };
                self.stats.deaths.push(notice.clone());
                declared.push(notice);
            }
        }
        declared
    }

    /// What the core has seen so far.
    pub fn stats(&self) -> &DetectorStats {
        &self.stats
    }

    /// Consume the core, yielding its stats.
    pub fn into_stats(self) -> DetectorStats {
        self.stats
    }
}

/// Anchor-side heartbeat monitor. Polls the board every `every`; a
/// watched non-anchor slot whose beat fails to advance for `k_misses`
/// consecutive polls is declared dead: the slot is unwatched, the
/// `heartbeats_missed` counter is charged, and `on_death` runs on the
/// detector thread (the driver's recovery hook).
pub struct FailureDetector {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<DetectorStats>>,
}

impl FailureDetector {
    /// Spawn the monitor. `counters` is the anchor's set — every missed
    /// deadline bumps `heartbeats_missed` so detector health shows up in
    /// `counters_total` and bench artifacts.
    pub fn spawn<F>(
        board: Arc<HeartbeatBoard>,
        every: Duration,
        k_misses: u64,
        counters: Arc<Counters>,
        mut on_death: F,
    ) -> FailureDetector
    where
        F: FnMut(LocalityId) + Send + 'static,
    {
        assert!(k_misses > 0, "failure detector needs at least one missed beat");
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let handle = std::thread::Builder::new()
            .name("px-failure-detector".into())
            .spawn(move || {
                let mut core = DetectorCore::new(board.capacity(), k_misses);
                let epoch = Instant::now();
                while !flag.load(Ordering::SeqCst) {
                    std::thread::sleep(every);
                    for death in core.poll(&board, epoch.elapsed(), &counters) {
                        on_death(death.locality);
                    }
                }
                core.into_stats()
            })
            .expect("spawn failure detector");
        FailureDetector { stop, handle: Some(handle) }
    }

    /// Stop the monitor and collect its stats.
    pub fn stop(mut self) -> DetectorStats {
        self.stop.store(true, Ordering::SeqCst);
        match self.handle.take() {
            Some(h) => h.join().unwrap_or_default(),
            None => DetectorStats::default(),
        }
    }
}

impl Drop for FailureDetector {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::DetExecutor;
    use std::cell::RefCell;
    use std::rc::Rc;
    use std::sync::mpsc;

    #[test]
    fn board_tracks_enroll_halt_unwatch() {
        let board = HeartbeatBoard::new(4);
        assert_eq!(board.capacity(), 4);
        board.enroll(2);
        assert!(board.is_beating(2) && board.is_watched(2));
        board.halt(2);
        assert!(!board.is_beating(2) && board.is_watched(2), "halted slot stays watched");
        board.unwatch(2);
        assert!(!board.is_watched(2));
        board.beat(1);
        board.beat(1);
        assert_eq!(board.beat_of(1), 2);
        assert_eq!(board.beat_of(0), 0);
    }

    /// Virtual-clock harness: members beat every 1ms (integer instants),
    /// the detector polls every 1ms offset by 500µs (never coinciding
    /// with a beat), and deaths are collected with their virtual
    /// timestamps. Returns `(deaths, core stats, counters)` after running
    /// to `horizon`.
    fn run_virtual_detector(
        board: &Arc<HeartbeatBoard>,
        k_misses: u64,
        horizon: Duration,
        script: impl FnOnce(&mut DetExecutor, Arc<HeartbeatBoard>),
    ) -> (Vec<(Duration, DeathNotice)>, DetectorStats, Arc<Counters>) {
        let counters = Arc::new(Counters::default());
        let deaths: Rc<RefCell<Vec<(Duration, DeathNotice)>>> = Rc::new(RefCell::new(Vec::new()));
        let mut ex = DetExecutor::new();
        let beat_board = board.clone();
        ex.schedule_every(Duration::from_millis(1), move |_| {
            for l in 0..beat_board.capacity() {
                if beat_board.is_beating(l as LocalityId) {
                    beat_board.beat(l as LocalityId);
                }
            }
            true
        });
        let core = Rc::new(RefCell::new(DetectorCore::new(board.capacity(), k_misses)));
        let poll_board = board.clone();
        let poll_counters = counters.clone();
        let poll_core = core.clone();
        let poll_deaths = deaths.clone();
        ex.schedule_in(Duration::from_micros(500), move |ex| {
            ex.schedule_every(Duration::from_millis(1), move |ex| {
                let now = ex.now();
                for d in poll_core.borrow_mut().poll(&poll_board, now, &poll_counters) {
                    poll_deaths.borrow_mut().push((now, d));
                }
                true
            });
        });
        script(&mut ex, board.clone());
        ex.run_until(horizon);
        // The pending re-armed poll event still holds a clone of `core`;
        // drop the executor before unwrapping.
        drop(ex);
        let deaths = deaths.borrow().clone();
        let stats = Rc::try_unwrap(core)
            .ok()
            .expect("sole core owner after run")
            .into_inner()
            .into_stats();
        (deaths, stats, counters)
    }

    #[test]
    fn detector_declares_death_after_k_missed_beats_at_exact_virtual_time() {
        let board = HeartbeatBoard::new(4);
        for l in 1..4 {
            board.enroll(l);
        }
        // Beats land at 1,2,3,4,5 ms; the halt at 5.2ms stops slot 2's
        // beat. Polls run at 1.5, 2.5, ... ms: the poll at 5.5ms still
        // sees the 5ms beat, 6.5/7.5/8.5 miss — with k=3 the death is
        // declared at exactly 8.5ms with detection latency exactly 2ms
        // (first miss observed at 6.5ms).
        let (deaths, stats, counters) = run_virtual_detector(
            &board,
            3,
            Duration::from_millis(20),
            |ex, board| {
                ex.schedule_in(Duration::from_micros(5200), move |_| board.halt(2));
            },
        );
        assert_eq!(deaths.len(), 1, "exactly one death declared");
        let (at, notice) = &deaths[0];
        assert_eq!(notice.locality, 2);
        assert_eq!(notice.missed, 3);
        assert_eq!(*at, Duration::from_micros(8500));
        assert_eq!(notice.detection_latency, Duration::from_millis(2));
        assert!(!board.is_watched(2), "declared-dead slot is unwatched");
        assert!(board.is_watched(1) && board.is_watched(3), "survivors stay watched");
        // Slot 2 missed exactly 3 polls; nothing else ever missed.
        assert_eq!(stats.heartbeats_missed, 3);
        assert_eq!(counters.heartbeats_missed.get(), 3);
    }

    #[test]
    fn gracefully_unwatched_slot_is_never_declared() {
        let board = HeartbeatBoard::new(3);
        board.enroll(1);
        board.enroll(2);
        let (deaths, stats, _) = run_virtual_detector(
            &board,
            2,
            Duration::from_millis(50),
            |ex, board| {
                // Graceful retirement at 3.2ms: unwatch stops the beat
                // *and* the monitoring in one step.
                ex.schedule_in(Duration::from_micros(3200), move |_| board.unwatch(1));
            },
        );
        assert!(deaths.is_empty(), "graceful exit must not look like a crash");
        assert_eq!(stats.heartbeats_missed, 0);
        assert!(board.is_watched(2), "the live member stays watched");
    }

    #[test]
    fn anchor_is_never_declared_dead() {
        let board = HeartbeatBoard::new(2);
        board.enroll(0);
        board.enroll(1);
        let (deaths, stats, _) = run_virtual_detector(
            &board,
            2,
            Duration::from_millis(50),
            |ex, board| {
                // Even a silent anchor is not the detector's call.
                ex.schedule_in(Duration::from_micros(2200), move |_| board.halt(0));
            },
        );
        assert!(deaths.is_empty());
        assert_eq!(stats.heartbeats_missed, 0, "anchor slot is never even polled");
        assert!(board.is_watched(0), "the anchor stays watched");
    }

    /// The OS-thread wrapper still works end to end (spawn, poll loop,
    /// stop/stats) — no sleeps in the test: the victim is halted before
    /// the detector starts, so the first k polls already miss.
    #[test]
    fn threaded_detector_wrapper_declares_death() {
        let board = HeartbeatBoard::new(4);
        for l in 1..4 {
            board.enroll(l);
        }
        board.halt(2);
        let beater = Heartbeater::spawn(board.clone(), Duration::from_micros(200));
        let counters = Arc::new(Counters::default());
        let (tx, rx) = mpsc::channel();
        let detector = FailureDetector::spawn(
            board.clone(),
            Duration::from_micros(500),
            3,
            counters.clone(),
            move |l| tx.send(l).unwrap(),
        );
        let dead = rx.recv_timeout(Duration::from_secs(5)).expect("death declared");
        assert_eq!(dead, 2);
        assert!(!board.is_watched(2), "declared-dead slot is unwatched");
        let stats = detector.stop();
        beater.stop();
        assert_eq!(stats.deaths.len(), 1);
        assert_eq!(stats.deaths[0].locality, 2);
        assert!(stats.deaths[0].missed >= 3);
        assert!(stats.heartbeats_missed >= 3);
        assert_eq!(counters.heartbeats_missed.get(), stats.heartbeats_missed);
    }
}
