//! Error type shared by all ParalleX runtime components.
//!
//! (Hand-written `Display`/`Error` impls instead of a `thiserror` derive
//! so the crate stays dependency-free for offline builds.)

/// Errors surfaced by the ParalleX runtime.
///
/// LCOs propagate `PxError` through continuations (a future set to an error
/// state delivers `Err` to every registered continuation), mirroring HPX's
/// exception forwarding across asynchronous boundaries.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PxError {
    /// An AGAS lookup failed: the GID was never bound or was unbound.
    Unresolved(String),
    /// A parcel referenced an action id that no locality registered.
    UnknownAction(u32),
    /// Wire-format decode failure (truncated or corrupt parcel).
    Wire(String),
    /// An LCO was used against its protocol (e.g. double-set of a future).
    LcoProtocol(String),
    /// A value-producing task failed; the error text is forwarded.
    TaskFailed(String),
    /// The runtime is shutting down; no further work is accepted.
    ShuttingDown,
    /// Simulated network failure (used by failure-injection tests).
    Net(String),
}

impl std::fmt::Display for PxError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PxError::Unresolved(g) => write!(f, "AGAS: unresolved gid {g}"),
            PxError::UnknownAction(id) => write!(f, "action manager: unknown action id {id}"),
            PxError::Wire(m) => write!(f, "wire: {m}"),
            PxError::LcoProtocol(m) => write!(f, "LCO protocol violation: {m}"),
            PxError::TaskFailed(m) => write!(f, "remote/async task failed: {m}"),
            PxError::ShuttingDown => write!(f, "runtime is shutting down"),
            PxError::Net(m) => write!(f, "network: {m}"),
        }
    }
}

impl std::error::Error for PxError {}

/// Convenience alias used across the runtime.
pub type PxResult<T> = Result<T, PxError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn errors_format_their_payload() {
        let e = PxError::UnknownAction(42);
        assert!(e.to_string().contains("42"));
        let e = PxError::Unresolved("gid{7,9}".into());
        assert!(e.to_string().contains("gid{7,9}"));
    }

    #[test]
    fn errors_are_cloneable_and_comparable() {
        let e = PxError::ShuttingDown;
        assert_eq!(e.clone(), PxError::ShuttingDown);
    }

    #[test]
    fn display_matches_previous_derive_output() {
        assert_eq!(PxError::ShuttingDown.to_string(), "runtime is shutting down");
        assert_eq!(PxError::Wire("short".into()).to_string(), "wire: short");
        assert_eq!(
            PxError::TaskFailed("boom".into()).to_string(),
            "remote/async task failed: boom"
        );
    }
}
