//! Error type shared by all ParalleX runtime components.

use thiserror::Error;

/// Errors surfaced by the ParalleX runtime.
///
/// LCOs propagate `PxError` through continuations (a future set to an error
/// state delivers `Err` to every registered continuation), mirroring HPX's
/// exception forwarding across asynchronous boundaries.
#[derive(Error, Debug, Clone, PartialEq, Eq)]
pub enum PxError {
    /// An AGAS lookup failed: the GID was never bound or was unbound.
    #[error("AGAS: unresolved gid {0}")]
    Unresolved(String),
    /// A parcel referenced an action id that no locality registered.
    #[error("action manager: unknown action id {0}")]
    UnknownAction(u32),
    /// Wire-format decode failure (truncated or corrupt parcel).
    #[error("wire: {0}")]
    Wire(String),
    /// An LCO was used against its protocol (e.g. double-set of a future).
    #[error("LCO protocol violation: {0}")]
    LcoProtocol(String),
    /// A value-producing task failed; the error text is forwarded.
    #[error("remote/async task failed: {0}")]
    TaskFailed(String),
    /// The runtime is shutting down; no further work is accepted.
    #[error("runtime is shutting down")]
    ShuttingDown,
    /// Simulated network failure (used by failure-injection tests).
    #[error("network: {0}")]
    Net(String),
}

/// Convenience alias used across the runtime.
pub type PxResult<T> = Result<T, PxError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn errors_format_their_payload() {
        let e = PxError::UnknownAction(42);
        assert!(e.to_string().contains("42"));
        let e = PxError::Unresolved("gid{7,9}".into());
        assert!(e.to_string().contains("gid{7,9}"));
    }

    #[test]
    fn errors_are_cloneable_and_comparable() {
        let e = PxError::ShuttingDown;
        assert_eq!(e.clone(), PxError::ShuttingDown);
    }
}
