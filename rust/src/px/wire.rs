//! Parcel wire format: a small, explicit little-endian encoder/decoder.
//!
//! The offline build has no serde, and HPX itself ships a bespoke
//! portable-binary archive for parcel serialization, so this module plays
//! that role: action arguments and parcel envelopes are encoded with
//! [`Enc`] and decoded with [`Dec`]. All multi-byte integers are
//! little-endian; sequences are length-prefixed with `u32`.

use super::error::{PxError, PxResult};
use super::gid::Gid;

/// Append-only binary encoder.
#[derive(Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    /// Fresh empty encoder.
    pub fn new() -> Enc {
        Enc { buf: Vec::new() }
    }

    /// Encoder with pre-reserved capacity (hot-path parcels).
    pub fn with_capacity(n: usize) -> Enc {
        Enc { buf: Vec::with_capacity(n) }
    }

    /// Finish and take the buffer.
    pub fn finish(self) -> Vec<u8> {
        self.buf
    }

    /// Bytes written so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when nothing has been written.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn u8(&mut self, v: u8) -> &mut Self {
        self.buf.push(v);
        self
    }

    pub fn u16(&mut self, v: u16) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    /// Overwrite a previously written `u32` at byte `offset` — the
    /// back-patch idiom for count/length headers whose value is only
    /// known after the payload is encoded (e.g. the `ACT_AMR_PUSH_BATCH`
    /// entry count). Panics if the offset was never written.
    pub fn patch_u32(&mut self, offset: usize, v: u32) -> &mut Self {
        self.buf[offset..offset + 4].copy_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u128(&mut self, v: u128) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn i64(&mut self, v: i64) -> &mut Self {
        self.u64(v as u64)
    }

    pub fn f64(&mut self, v: f64) -> &mut Self {
        self.u64(v.to_bits())
    }

    pub fn bool(&mut self, v: bool) -> &mut Self {
        self.u8(v as u8)
    }

    pub fn gid(&mut self, g: Gid) -> &mut Self {
        self.u128(g.raw())
    }

    /// Length-prefixed raw bytes.
    pub fn bytes(&mut self, v: &[u8]) -> &mut Self {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
        self
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) -> &mut Self {
        self.bytes(s.as_bytes())
    }

    /// Length-prefixed f64 slice (the AMR ghost-zone payload type).
    pub fn f64s(&mut self, v: &[f64]) -> &mut Self {
        self.u32(v.len() as u32);
        self.buf.reserve(v.len() * 8);
        for x in v {
            self.buf.extend_from_slice(&x.to_bits().to_le_bytes());
        }
        self
    }
}

/// Cursor-based binary decoder over a byte slice.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    /// Decode from `buf` starting at offset 0.
    pub fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    /// Bytes left to decode.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> PxResult<&'a [u8]> {
        if self.remaining() < n {
            return Err(PxError::Wire(format!(
                "truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> PxResult<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> PxResult<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> PxResult<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> PxResult<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn u128(&mut self) -> PxResult<u128> {
        Ok(u128::from_le_bytes(self.take(16)?.try_into().unwrap()))
    }

    pub fn i64(&mut self) -> PxResult<i64> {
        Ok(self.u64()? as i64)
    }

    pub fn f64(&mut self) -> PxResult<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn bool(&mut self) -> PxResult<bool> {
        Ok(self.u8()? != 0)
    }

    pub fn gid(&mut self) -> PxResult<Gid> {
        Ok(Gid::from_raw(self.u128()?))
    }

    /// Length-prefixed raw bytes.
    pub fn bytes(&mut self) -> PxResult<&'a [u8]> {
        let n = self.u32()? as usize;
        self.take(n)
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self) -> PxResult<String> {
        let b = self.bytes()?;
        String::from_utf8(b.to_vec()).map_err(|e| PxError::Wire(format!("bad utf8: {e}")))
    }

    /// Length-prefixed f64 vector.
    pub fn f64s(&mut self) -> PxResult<Vec<f64>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 8)?;
        let mut out = Vec::with_capacity(n);
        for c in raw.chunks_exact(8) {
            out.push(f64::from_bits(u64::from_le_bytes(c.try_into().unwrap())));
        }
        Ok(out)
    }

    /// Assert the whole buffer was consumed (catches protocol drift).
    pub fn expect_end(&self) -> PxResult<()> {
        if self.remaining() == 0 {
            Ok(())
        } else {
            Err(PxError::Wire(format!("{} trailing bytes", self.remaining())))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::px::gid::GidKind;
    use crate::testkit::prop::{prop_check, Rng};

    #[test]
    fn scalar_roundtrip() {
        let mut e = Enc::new();
        e.u8(7).u16(513).u32(70_000).u64(1 << 40).f64(-2.5).bool(true).str("hello");
        let buf = e.finish();
        let mut d = Dec::new(&buf);
        assert_eq!(d.u8().unwrap(), 7);
        assert_eq!(d.u16().unwrap(), 513);
        assert_eq!(d.u32().unwrap(), 70_000);
        assert_eq!(d.u64().unwrap(), 1 << 40);
        assert_eq!(d.f64().unwrap(), -2.5);
        assert!(d.bool().unwrap());
        assert_eq!(d.str().unwrap(), "hello");
        d.expect_end().unwrap();
    }

    #[test]
    fn gid_roundtrip() {
        let g = Gid::new(9, GidKind::Dataflow, 1234567);
        let mut e = Enc::new();
        e.gid(g);
        let buf = e.finish();
        assert_eq!(Dec::new(&buf).gid().unwrap(), g);
    }

    #[test]
    fn truncated_input_is_an_error_not_a_panic() {
        let mut e = Enc::new();
        e.u64(42);
        let buf = e.finish();
        let mut d = Dec::new(&buf[..5]);
        assert!(matches!(d.u64(), Err(PxError::Wire(_))));
    }

    #[test]
    fn patch_u32_rewrites_a_header_in_place() {
        let mut e = Enc::new();
        let at = e.len();
        e.u32(0); // placeholder count
        e.u64(7).u64(9);
        e.patch_u32(at, 2);
        let mut d = Dec::new(&e.finish());
        assert_eq!(d.u32().unwrap(), 2);
        assert_eq!(d.u64().unwrap(), 7);
        assert_eq!(d.u64().unwrap(), 9);
        d.expect_end().unwrap();
    }

    #[test]
    fn trailing_bytes_detected() {
        let mut e = Enc::new();
        e.u32(1).u32(2);
        let buf = e.finish();
        let mut d = Dec::new(&buf);
        d.u32().unwrap();
        assert!(d.expect_end().is_err());
    }

    #[test]
    fn bytes_with_bad_length_prefix_fails_cleanly() {
        // Length prefix claims 1000 bytes but only 2 follow.
        let mut e = Enc::new();
        e.u32(1000).u16(7);
        let buf = e.finish();
        assert!(Dec::new(&buf).bytes().is_err());
    }

    #[test]
    fn empty_sequences_roundtrip() {
        // The empty-batch envelope: zero-length f64 slice, byte slice
        // and string must all encode to a bare length prefix and decode
        // back to empty, with the cursor exactly consumed.
        let mut e = Enc::new();
        e.f64s(&[]).bytes(&[]).str("");
        let buf = e.finish();
        assert_eq!(buf.len(), 12, "three u32 length prefixes, no payload");
        let mut d = Dec::new(&buf);
        assert_eq!(d.f64s().unwrap(), Vec::<f64>::new());
        assert_eq!(d.bytes().unwrap(), &[] as &[u8]);
        assert_eq!(d.str().unwrap(), "");
        d.expect_end().unwrap();
    }

    #[test]
    fn patch_u32_at_buffer_boundaries() {
        // Patch the very first and the very last u32 of the buffer —
        // the `offset..offset + 4` slice must sit flush against both
        // ends without over- or under-running.
        let mut e = Enc::new();
        let head = e.len();
        e.u32(0);
        e.u64(77);
        let tail = e.len();
        e.u32(0);
        e.patch_u32(head, 0xAAAA_BBBB).patch_u32(tail, 0xCCCC_DDDD);
        let buf = e.finish();
        assert_eq!(tail, buf.len() - 4);
        let mut d = Dec::new(&buf);
        assert_eq!(d.u32().unwrap(), 0xAAAA_BBBB);
        assert_eq!(d.u64().unwrap(), 77);
        assert_eq!(d.u32().unwrap(), 0xCCCC_DDDD);
        d.expect_end().unwrap();
    }

    #[test]
    fn every_truncation_of_a_message_errors_cleanly() {
        // Chop a mixed message after every possible prefix length and
        // decode: each cut must surface `PxError::Wire` from one of the
        // fields — never a panic, never an Ok full decode off garbage.
        let mut e = Enc::new();
        e.u8(3).u32(70_000).f64s(&[1.5, -2.5]).bytes(b"xyz").str("end").u64(99);
        let buf = e.finish();
        let whole = {
            let mut d = Dec::new(&buf);
            let decode_all = |d: &mut Dec| -> PxResult<()> {
                d.u8()?;
                d.u32()?;
                d.f64s()?;
                d.bytes()?;
                d.str()?;
                d.u64()?;
                d.expect_end()
            };
            decode_all(&mut d)
        };
        whole.unwrap();
        for cut in 0..buf.len() {
            let mut d = Dec::new(&buf[..cut]);
            let res: PxResult<()> = (|| {
                d.u8()?;
                d.u32()?;
                d.f64s()?;
                d.bytes()?;
                d.str()?;
                d.u64()?;
                d.expect_end()
            })();
            match res {
                Err(PxError::Wire(msg)) => {
                    assert!(
                        msg.contains("truncated") || msg.contains("trailing"),
                        "cut at {cut}: unexpected wire error: {msg}"
                    )
                }
                Err(e) => panic!("cut at {cut}: non-wire error: {e}"),
                Ok(()) => panic!("cut at {cut}: truncated decode succeeded"),
            }
        }
    }

    #[test]
    fn prop_f64s_roundtrip_including_specials() {
        prop_check("wire f64s roundtrip", 200, |rng: &mut Rng| {
            let mut v = rng.f64_vec(0, 64, -1e12, 1e12);
            if rng.chance(0.3) {
                v.push(f64::INFINITY);
                v.push(f64::NEG_INFINITY);
                v.push(0.0);
                v.push(-0.0);
            }
            let mut e = Enc::new();
            e.f64s(&v);
            let buf = e.finish();
            let got = Dec::new(&buf).f64s().unwrap();
            assert_eq!(v.len(), got.len());
            for (a, b) in v.iter().zip(&got) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        });
    }

    #[test]
    fn prop_mixed_sequences_roundtrip() {
        prop_check("wire mixed roundtrip", 200, |rng: &mut Rng| {
            let raw = rng.bytes(128);
            let s: String = (0..rng.below(20)).map(|i| (b'a' + (i % 26) as u8) as char).collect();
            let x = rng.next_u64();
            let mut e = Enc::new();
            e.bytes(&raw).str(&s).u64(x);
            let buf = e.finish();
            let mut d = Dec::new(&buf);
            assert_eq!(d.bytes().unwrap(), &raw[..]);
            assert_eq!(d.str().unwrap(), s);
            assert_eq!(d.u64().unwrap(), x);
            d.expect_end().unwrap();
        });
    }
}
