//! Action registry: names the functions parcels can apply remotely.
//!
//! In ParalleX an *action* is a registered, globally agreed-upon function
//! id; a parcel carries `(dest gid, action id, serialized args)` and the
//! receiving action manager spawns a PX-thread running the registered
//! body. Applications extend the runtime by registering their own actions
//! at boot (the paper's "application specific components", Fig 1); ids at
//! or above [`RESERVED_ACTION_BASE`] are reserved for runtime builtins.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use super::error::{PxError, PxResult};
use super::locality::LocalityCtx;
use super::parcel::{ActionId, Parcel};

/// Action ids ≥ this are runtime builtins (future set/get, ping, ...).
pub const RESERVED_ACTION_BASE: ActionId = 0xFFFF_FF00;

/// Builtin: resolve a registered `Future<Vec<f64>>` component.
pub const ACT_SET_FUTURE_F64S: ActionId = RESERVED_ACTION_BASE + 1;
/// Builtin: resolve a registered `Future<Vec<f64>>` component with an error.
pub const ACT_SET_FUTURE_ERROR: ActionId = RESERVED_ACTION_BASE + 2;
/// Builtin: liveness ping — replies on the continuation with `[seq]`.
pub const ACT_PING: ActionId = RESERVED_ACTION_BASE + 3;

/// Application action-id block reserved for the AMR driver (below the
/// builtin range; ids must agree across every locality, like statically
/// linked function pointers).
pub const AMR_ACTION_BASE: ActionId = 0x00A3_0000;

/// AMR: deliver one serialized dataflow input (ghost / taper /
/// restriction fragment or self state) to a block-step task on the
/// block's current home locality. Registered by the distributed AMR
/// driver at epoch setup; the parcel's `dest` GID names the block.
/// Since ghost batching landed this is the *re-forward* path (a batch
/// entry chasing a migrated block) and the unbatched fallback.
pub const ACT_AMR_PUSH: ActionId = AMR_ACTION_BASE + 1;

/// AMR: deliver a *coalesced* set of dataflow inputs — every fragment
/// one producer step emitted toward one destination locality, in one
/// parcel, so a neighbour exchange pays the wire's base latency once
/// rather than per fragment (DESIGN.md §7). The parcel's `dest` GID
/// names the destination locality's batch-sink component, not a block;
/// each entry carries its own `BlockId` and is re-routed individually
/// if its block migrated while the batch was in flight.
pub const ACT_AMR_PUSH_BATCH: ActionId = AMR_ACTION_BASE + 2;

/// The body of an action: runs as a PX-thread on the destination locality.
pub type ActionFn = dyn Fn(&Arc<LocalityCtx>, Parcel) + Send + Sync;

/// Registry shared by every locality of a runtime instance (action ids
/// must agree globally, like function pointers linked into every rank).
#[derive(Default)]
pub struct ActionRegistry {
    map: RwLock<HashMap<ActionId, Arc<ActionFn>>>,
}

impl ActionRegistry {
    /// Empty registry.
    pub fn new() -> Arc<ActionRegistry> {
        Arc::new(ActionRegistry::default())
    }

    /// Register `f` under `id`. Re-registering an id is a programming
    /// error (actions are global, static agreements).
    pub fn register<F>(&self, id: ActionId, f: F)
    where
        F: Fn(&Arc<LocalityCtx>, Parcel) + Send + Sync + 'static,
    {
        let mut m = self.map.write().unwrap();
        assert!(!m.contains_key(&id), "action id {id:#x} registered twice");
        m.insert(id, Arc::new(f));
    }

    /// Register `f` under `id` unless an action already holds that id.
    /// Returns whether the registration happened. Used by subsystems that
    /// install the same action once per *runtime* but are entered once
    /// per *epoch* (e.g. the distributed AMR driver).
    pub fn register_if_absent<F>(&self, id: ActionId, f: F) -> bool
    where
        F: Fn(&Arc<LocalityCtx>, Parcel) + Send + Sync + 'static,
    {
        let mut m = self.map.write().unwrap();
        if m.contains_key(&id) {
            return false;
        }
        m.insert(id, Arc::new(f));
        true
    }

    /// Look up an action body.
    pub fn get(&self, id: ActionId) -> PxResult<Arc<ActionFn>> {
        self.map
            .read()
            .unwrap()
            .get(&id)
            .cloned()
            .ok_or(PxError::UnknownAction(id))
    }

    /// Registered action count (diagnostics).
    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    /// True when no actions are registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_and_get() {
        let r = ActionRegistry::new();
        r.register(7, |_, _| {});
        assert!(r.get(7).is_ok());
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn unknown_action_is_error() {
        let r = ActionRegistry::new();
        assert!(matches!(r.get(9), Err(PxError::UnknownAction(9))));
    }

    #[test]
    #[should_panic(expected = "registered twice")]
    fn double_registration_panics() {
        let r = ActionRegistry::new();
        r.register(7, |_, _| {});
        r.register(7, |_, _| {});
    }
}
