//! Global identifiers (GIDs) for first-class ParalleX objects.
//!
//! In ParalleX every referentiable entity — threads, LCOs, data blocks,
//! processes — carries an immutable global name that is decoupled from its
//! current placement. A [`Gid`] packs a 32-bit *birthplace* locality (used
//! only as a hint and for human-readable debugging; the authoritative
//! mapping lives in AGAS), a 16-bit type tag and a 64-bit sequence number
//! into a single `u128` so GIDs are cheap to copy, hash and serialize.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

/// Identifies a locality (≈ a cluster node in the paper's terminology).
pub type LocalityId = u32;

/// Type tag carried inside a GID. Purely diagnostic: AGAS does not
/// interpret it, but counters and debug output group by it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u16)]
pub enum GidKind {
    /// Untyped / application-defined component.
    Component = 0,
    /// A future LCO.
    Future = 1,
    /// A dataflow LCO.
    Dataflow = 2,
    /// A lightweight PX-thread (threads are first-class objects).
    Thread = 3,
    /// An AMR data block.
    Block = 4,
    /// A ParalleX process.
    Process = 5,
}

impl GidKind {
    fn from_u16(v: u16) -> GidKind {
        match v {
            1 => GidKind::Future,
            2 => GidKind::Dataflow,
            3 => GidKind::Thread,
            4 => GidKind::Block,
            5 => GidKind::Process,
            _ => GidKind::Component,
        }
    }
}

/// A 128-bit global identifier: `[locality:32 | kind:16 | reserved:16 | seq:64]`.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Gid(pub u128);

impl Gid {
    /// The invalid / null GID. Never bound in AGAS.
    pub const NULL: Gid = Gid(0);

    /// Assemble a GID from parts.
    pub fn new(birthplace: LocalityId, kind: GidKind, seq: u64) -> Gid {
        Gid(((birthplace as u128) << 96) | ((kind as u16 as u128) << 80) | seq as u128)
    }

    /// The locality on which this GID was minted (a placement *hint* only).
    pub fn birthplace(self) -> LocalityId {
        (self.0 >> 96) as u32
    }

    /// The diagnostic type tag.
    pub fn kind(self) -> GidKind {
        GidKind::from_u16((self.0 >> 80) as u16)
    }

    /// The per-allocator sequence number.
    pub fn seq(self) -> u64 {
        self.0 as u64
    }

    /// True for the null GID.
    pub fn is_null(self) -> bool {
        self.0 == 0
    }

    /// Raw value for wire encoding.
    pub fn raw(self) -> u128 {
        self.0
    }

    /// Rebuild from a wire value.
    pub fn from_raw(v: u128) -> Gid {
        Gid(v)
    }
}

impl fmt::Debug for Gid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_null() {
            write!(f, "gid{{null}}")
        } else {
            write!(f, "gid{{L{} {:?} #{}}}", self.birthplace(), self.kind(), self.seq())
        }
    }
}

impl fmt::Display for Gid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}

/// Mints GIDs unique within one runtime instance. Each locality owns one
/// allocator; sequence numbers never repeat (64-bit monotonic counter).
pub struct GidAllocator {
    locality: LocalityId,
    next: AtomicU64,
}

impl GidAllocator {
    /// New allocator for `locality`, starting at sequence 1 (0 is reserved
    /// so that `Gid::NULL` can never be minted).
    pub fn new(locality: LocalityId) -> Self {
        GidAllocator { locality, next: AtomicU64::new(1) }
    }

    /// Mint a fresh GID of the given kind.
    pub fn alloc(&self, kind: GidKind) -> Gid {
        let seq = self.next.fetch_add(1, Ordering::Relaxed);
        Gid::new(self.locality, kind, seq)
    }

    /// Number of GIDs minted so far.
    pub fn minted(&self) -> u64 {
        self.next.load(Ordering::Relaxed) - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop::{prop_check, Rng};

    #[test]
    fn pack_unpack_roundtrip() {
        let g = Gid::new(7, GidKind::Dataflow, 0xDEAD_BEEF_1234);
        assert_eq!(g.birthplace(), 7);
        assert_eq!(g.kind(), GidKind::Dataflow);
        assert_eq!(g.seq(), 0xDEAD_BEEF_1234);
    }

    #[test]
    fn null_gid_is_never_minted() {
        let a = GidAllocator::new(0);
        for _ in 0..100 {
            assert!(!a.alloc(GidKind::Component).is_null());
        }
        assert_eq!(a.minted(), 100);
    }

    #[test]
    fn allocators_on_distinct_localities_never_collide() {
        let a = GidAllocator::new(1);
        let b = GidAllocator::new(2);
        let ga: Vec<Gid> = (0..50).map(|_| a.alloc(GidKind::Thread)).collect();
        let gb: Vec<Gid> = (0..50).map(|_| b.alloc(GidKind::Thread)).collect();
        for x in &ga {
            assert!(!gb.contains(x));
        }
    }

    #[test]
    fn prop_pack_unpack_any_fields() {
        prop_check("gid pack/unpack", 500, |rng: &mut Rng| {
            let loc = rng.next_u32();
            let seq = rng.next_u64();
            let kind = match rng.below(6) {
                0 => GidKind::Component,
                1 => GidKind::Future,
                2 => GidKind::Dataflow,
                3 => GidKind::Thread,
                4 => GidKind::Block,
                _ => GidKind::Process,
            };
            let g = Gid::new(loc, kind, seq);
            assert_eq!(g.birthplace(), loc);
            assert_eq!(g.kind(), kind);
            assert_eq!(g.seq(), seq);
            let g2 = Gid::from_raw(g.raw());
            assert_eq!(g, g2);
        });
    }

    #[test]
    fn debug_format_mentions_locality_and_kind() {
        let g = Gid::new(3, GidKind::Block, 9);
        let s = format!("{g:?}");
        assert!(s.contains("L3") && s.contains("Block") && s.contains("#9"));
    }
}
