//! The ParalleX runtime core (the paper's §II, HPX-like).
//!
//! Modules mirror the six ParalleX management principles and the Fig 1
//! architecture walkthrough:
//!
//! * [`gid`] / [`agas`] — global names and the Active Global Address Space
//! * [`parcel`] / [`wire`] / [`net`] / [`action`] — parcel transport,
//!   serialization, the simulated interconnect and the action manager
//! * [`thread`] / [`sched`] — HPX-thread manager and scheduling policies
//! * [`lco`] — Local Control Objects (future, dataflow, mutex, semaphore,
//!   full-empty bit, and-gate, global barrier)
//! * [`counters`] — the performance-counter monitoring framework
//! * [`trace`] / [`hist`] — the flight-recorder causal tracing layer and
//!   its latency histograms (counts say *how many*; these say *when*,
//!   *how long*, and *because of what*)
//! * [`recovery`] — heartbeat failure detection for unplanned locality
//!   death (the crash-tolerance layer over elastic membership)
//! * [`locality`] / [`runtime`] — composition into localities and the
//!   bootable multi-locality runtime

pub mod action;
pub mod agas;
pub mod counters;
pub mod error;
pub mod gid;
pub mod hist;
pub mod lco;
pub mod lockfree;
pub mod locality;
pub mod net;
pub mod parcel;
pub mod recovery;
pub mod runtime;
pub mod sched;
pub mod thread;
pub mod trace;
pub mod wire;

pub use action::{ActionRegistry, RESERVED_ACTION_BASE};
pub use agas::{Agas, AgasClient, Placement};
pub use counters::{Counter, CounterSnapshot, Counters};
pub use error::{PxError, PxResult};
pub use gid::{Gid, GidAllocator, GidKind, LocalityId};
pub use hist::Histogram;
pub use lco::{AndGate, CountingSemaphore, Dataflow, FullEmptyBit, Future, GlobalBarrier, PxMutex};
pub use locality::LocalityCtx;
pub use net::{NetModel, SimNet};
pub use parcel::{ActionId, Parcel};
pub use recovery::{DeathNotice, DetectorStats, FailureDetector, HeartbeatBoard, Heartbeater};
pub use runtime::{Membership, PxConfig, PxRuntime, RetireReport, SchedPolicyKind};
pub use sched::{GlobalQueue, LocalPriority, MutexQueue, Policy, Priority, Task};
pub use thread::{
    global_queue_manager, local_priority_manager, mutex_queue_manager, Spawner, ThreadManager,
};
pub use trace::{CausalSummary, OwnedEvent, OwnedRing, TraceCtx, TraceStats};
