//! AGAS — the Active Global Address Space.
//!
//! AGAS maps immutable global names ([`Gid`]) to their *current* locality,
//! decoupling object identity from placement (§II). Unlike PGAS systems
//! (UPC/X10/Chapel) the mapping is **active**: objects migrate at runtime
//! and the address space follows them.
//!
//! Implementation: a partitioned home table — each GID's *birthplace*
//! locality owns its authoritative entry (as in HPX, where the locality
//! that mints a name serves resolutions for it) — fronted by per-locality
//! caches. Migration bumps a version number; stale cache hits are detected
//! by version and refreshed. In this in-process runtime the home table
//! partitions share one process, but all accesses go through the same
//! resolve/bind/migrate protocol a distributed AGAS would use, and the
//! cache-hit/miss counters feed the Fig 9-style overhead analysis.
//!
//! Migration is what makes the address space *active*: the coordinator's
//! load balancer calls [`AgasClient::migrate`] to move a hot AMR block,
//! in-flight parcels that reach the old home are hop-forwarded
//! (`parcels_forwarded`), and stale sender caches self-heal on their next
//! resolve. The full ordering of the migration protocol — handle
//! install, AGAS flip, driver re-route, drain — is DESIGN.md §6.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};

use super::counters::Counters;
use super::error::{PxError, PxResult};
use super::gid::{Gid, LocalityId};

/// An authoritative AGAS entry: where the object lives now.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Placement {
    /// Locality currently hosting the object.
    pub locality: LocalityId,
    /// Monotone version, bumped by each migration.
    pub version: u64,
}

/// One partition of the home table (owned by one locality).
#[derive(Default)]
struct HomePartition {
    entries: HashMap<Gid, Placement>,
}

/// The AGAS service shared by all localities of a runtime instance.
pub struct Agas {
    /// Partition `p` holds entries for GIDs whose birthplace is `p`.
    partitions: Vec<Mutex<HomePartition>>,
}

impl Agas {
    /// AGAS for a runtime with `n_localities` localities.
    pub fn new(n_localities: usize) -> Arc<Agas> {
        Arc::new(Agas {
            partitions: (0..n_localities).map(|_| Mutex::new(HomePartition::default())).collect(),
        })
    }

    fn partition(&self, gid: Gid) -> &Mutex<HomePartition> {
        &self.partitions[gid.birthplace() as usize % self.partitions.len()]
    }

    /// Bind a freshly minted GID to its initial locality.
    pub fn bind(&self, gid: Gid, locality: LocalityId) -> PxResult<()> {
        if gid.is_null() {
            return Err(PxError::LcoProtocol("cannot bind the null gid".into()));
        }
        let mut p = self.partition(gid).lock().unwrap();
        if p.entries.contains_key(&gid) {
            return Err(PxError::LcoProtocol(format!("gid {gid} already bound")));
        }
        p.entries.insert(gid, Placement { locality, version: 0 });
        Ok(())
    }

    /// Authoritative resolve (home-table read).
    pub fn resolve_home(&self, gid: Gid) -> PxResult<Placement> {
        let p = self.partition(gid).lock().unwrap();
        p.entries.get(&gid).copied().ok_or_else(|| PxError::Unresolved(gid.to_string()))
    }

    /// Move an object to `to`; bumps the version so caches self-invalidate.
    pub fn migrate(&self, gid: Gid, to: LocalityId) -> PxResult<Placement> {
        let mut p = self.partition(gid).lock().unwrap();
        match p.entries.get_mut(&gid) {
            Some(e) => {
                e.locality = to;
                e.version += 1;
                Ok(*e)
            }
            None => Err(PxError::Unresolved(gid.to_string())),
        }
    }

    /// Remove a binding (object destroyed).
    pub fn unbind(&self, gid: Gid) -> PxResult<()> {
        let mut p = self.partition(gid).lock().unwrap();
        p.entries.remove(&gid).map(|_| ()).ok_or_else(|| PxError::Unresolved(gid.to_string()))
    }

    /// Number of live bindings across all partitions (diagnostics).
    pub fn bindings(&self) -> usize {
        self.partitions.iter().map(|p| p.lock().unwrap().entries.len()).sum()
    }

    /// Every GID currently resolving to `locality` — the roster a
    /// retirement drain must migrate away before the locality's port
    /// detaches (DESIGN.md §8). Scans all home partitions; not a hot
    /// path (membership changes are rare relative to resolves).
    pub fn residents(&self, locality: LocalityId) -> Vec<Gid> {
        let mut out = Vec::new();
        for p in &self.partitions {
            let p = p.lock().unwrap();
            out.extend(p.entries.iter().filter(|(_, e)| e.locality == locality).map(|(g, _)| *g));
        }
        out
    }
}

/// Per-locality AGAS client with a read-through cache.
pub struct AgasClient {
    agas: Arc<Agas>,
    cache: RwLock<HashMap<Gid, Placement>>,
    counters: Arc<Counters>,
    /// This client's locality (for `is_local` checks).
    pub locality: LocalityId,
}

impl AgasClient {
    /// Client for `locality` backed by the shared service.
    pub fn new(agas: Arc<Agas>, locality: LocalityId, counters: Arc<Counters>) -> AgasClient {
        AgasClient { agas, cache: RwLock::new(HashMap::new()), counters, locality }
    }

    /// Bind and prime the local cache (objects are created locally).
    pub fn bind(&self, gid: Gid, locality: LocalityId) -> PxResult<()> {
        self.agas.bind(gid, locality)?;
        self.cache.write().unwrap().insert(gid, Placement { locality, version: 0 });
        Ok(())
    }

    /// Resolve with cache: the common (hit) path is a shared-lock map read.
    ///
    /// Staleness: a cached entry may point at a pre-migration locality.
    /// The action-manager protocol tolerates this — a parcel routed to a
    /// stale locality is *forwarded* by that locality after a fresh home
    /// resolve (see `locality.rs`), which also refreshes the sender's
    /// cache via `refresh`.
    pub fn resolve(&self, gid: Gid) -> PxResult<Placement> {
        if let Some(p) = self.cache.read().unwrap().get(&gid) {
            self.counters.agas_cache_hits.inc();
            return Ok(*p);
        }
        self.counters.agas_cache_misses.inc();
        let p = self.agas.resolve_home(gid)?;
        self.cache.write().unwrap().insert(gid, p);
        Ok(p)
    }

    /// Drop a (possibly stale) cache entry and re-resolve from home.
    pub fn refresh(&self, gid: Gid) -> PxResult<Placement> {
        self.counters.agas_cache_misses.inc();
        let p = self.agas.resolve_home(gid)?;
        self.cache.write().unwrap().insert(gid, p);
        Ok(p)
    }

    /// True when the object currently resolves to this locality.
    pub fn is_local(&self, gid: Gid) -> PxResult<bool> {
        Ok(self.resolve(gid)?.locality == self.locality)
    }

    /// Migrate an object and update this cache.
    pub fn migrate(&self, gid: Gid, to: LocalityId) -> PxResult<Placement> {
        let p = self.agas.migrate(gid, to)?;
        self.counters.migrations.inc();
        self.cache.write().unwrap().insert(gid, p);
        Ok(p)
    }

    /// Unbind and purge the cache entry.
    pub fn unbind(&self, gid: Gid) -> PxResult<()> {
        self.agas.unbind(gid)?;
        self.cache.write().unwrap().remove(&gid);
        Ok(())
    }

    /// Drop every cache entry pointing at `locality` — called on all
    /// clients when that locality retires, so no future resolve routes a
    /// parcel toward its (about to detach) port. The next resolve of an
    /// affected GID misses to the home table, which already points at
    /// the object's post-drain home.
    pub fn purge_locality(&self, locality: LocalityId) {
        self.cache.write().unwrap().retain(|_, p| p.locality != locality);
    }

    /// Shared service handle (for constructing sibling clients).
    pub fn service(&self) -> Arc<Agas> {
        self.agas.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::px::gid::{GidAllocator, GidKind};
    use crate::testkit::prop::{prop_check, Rng};

    fn setup(n: usize) -> (Arc<Agas>, Vec<AgasClient>) {
        let agas = Agas::new(n);
        let clients = (0..n as u32)
            .map(|l| AgasClient::new(agas.clone(), l, Arc::new(Counters::default())))
            .collect();
        (agas, clients)
    }

    #[test]
    fn bind_resolve_roundtrip() {
        let (_agas, clients) = setup(2);
        let alloc = GidAllocator::new(0);
        let g = alloc.alloc(GidKind::Block);
        clients[0].bind(g, 0).unwrap();
        assert_eq!(clients[1].resolve(g).unwrap().locality, 0);
        assert!(clients[0].is_local(g).unwrap());
        assert!(!clients[1].is_local(g).unwrap());
    }

    #[test]
    fn double_bind_rejected() {
        let (_agas, clients) = setup(1);
        let g = GidAllocator::new(0).alloc(GidKind::Component);
        clients[0].bind(g, 0).unwrap();
        assert!(matches!(clients[0].bind(g, 0), Err(PxError::LcoProtocol(_))));
    }

    #[test]
    fn null_gid_rejected() {
        let (agas, _) = setup(1);
        assert!(agas.bind(Gid::NULL, 0).is_err());
    }

    #[test]
    fn unresolved_gid_is_an_error() {
        let (_agas, clients) = setup(1);
        let g = GidAllocator::new(0).alloc(GidKind::Component);
        assert!(matches!(clients[0].resolve(g), Err(PxError::Unresolved(_))));
    }

    #[test]
    fn migrate_bumps_version_and_home_moves() {
        let (agas, clients) = setup(3);
        let g = GidAllocator::new(1).alloc(GidKind::Block);
        clients[1].bind(g, 1).unwrap();
        let p = clients[1].migrate(g, 2).unwrap();
        assert_eq!(p, Placement { locality: 2, version: 1 });
        assert_eq!(agas.resolve_home(g).unwrap().locality, 2);
    }

    #[test]
    fn stale_cache_detected_via_refresh() {
        let (_agas, clients) = setup(3);
        let g = GidAllocator::new(0).alloc(GidKind::Block);
        clients[0].bind(g, 0).unwrap();
        // Client 2 caches the original placement.
        assert_eq!(clients[2].resolve(g).unwrap().locality, 0);
        // Client 0 migrates the object away; client 2's cache is now stale.
        clients[0].migrate(g, 1).unwrap();
        assert_eq!(clients[2].resolve(g).unwrap().locality, 0, "cache returns stale value");
        assert_eq!(clients[2].refresh(g).unwrap().locality, 1, "refresh sees the move");
        assert_eq!(clients[2].resolve(g).unwrap().locality, 1, "cache updated");
    }

    #[test]
    fn unbind_purges() {
        let (agas, clients) = setup(1);
        let g = GidAllocator::new(0).alloc(GidKind::Future);
        clients[0].bind(g, 0).unwrap();
        assert_eq!(agas.bindings(), 1);
        clients[0].unbind(g).unwrap();
        assert_eq!(agas.bindings(), 0);
        assert!(clients[0].resolve(g).is_err());
    }

    #[test]
    fn residents_track_binds_and_migrations() {
        let (agas, clients) = setup(3);
        let alloc = GidAllocator::new(0);
        let a = alloc.alloc(GidKind::Block);
        let b = alloc.alloc(GidKind::Block);
        clients[0].bind(a, 0).unwrap();
        clients[1].bind(b, 1).unwrap();
        assert_eq!(agas.residents(0), vec![a]);
        assert_eq!(agas.residents(1), vec![b]);
        assert!(agas.residents(2).is_empty());
        clients[0].migrate(a, 2).unwrap();
        assert!(agas.residents(0).is_empty());
        assert_eq!(agas.residents(2), vec![a]);
        clients[1].unbind(b).unwrap();
        assert!(agas.residents(1).is_empty());
    }

    #[test]
    fn purge_locality_forces_home_reads() {
        let (_agas, clients) = setup(3);
        let alloc = GidAllocator::new(0);
        let g = alloc.alloc(GidKind::Block);
        clients[0].bind(g, 0).unwrap();
        assert_eq!(clients[2].resolve(g).unwrap().locality, 0); // cached
        clients[0].migrate(g, 1).unwrap();
        // Stale without purge; fresh after purging entries that point at 0.
        assert_eq!(clients[2].resolve(g).unwrap().locality, 0);
        clients[2].purge_locality(0);
        assert_eq!(clients[2].resolve(g).unwrap().locality, 1);
    }

    #[test]
    fn cache_hit_miss_counters() {
        let agas = Agas::new(1);
        let counters = Arc::new(Counters::default());
        let c = AgasClient::new(agas, 0, counters.clone());
        let g = GidAllocator::new(0).alloc(GidKind::Block);
        c.bind(g, 0).unwrap();
        c.resolve(g).unwrap(); // hit (primed by bind)
        c.resolve(g).unwrap(); // hit
        assert_eq!(counters.agas_cache_hits.get(), 2);
        assert_eq!(counters.agas_cache_misses.get(), 0);
    }

    #[test]
    fn prop_resolve_after_random_migrations_matches_home() {
        prop_check("agas migrate coherence", 100, |rng: &mut Rng| {
            let n = rng.range(1, 6);
            let (agas, clients) = setup(n);
            let alloc = GidAllocator::new(rng.range(0, n) as u32);
            let gids: Vec<Gid> = (0..rng.range(1, 20)).map(|_| alloc.alloc(GidKind::Block)).collect();
            for &g in &gids {
                let home = rng.range(0, n) as u32;
                clients[home as usize].bind(g, home).unwrap();
            }
            for _ in 0..rng.range(0, 50) {
                let g = gids[rng.range(0, gids.len())];
                let to = rng.range(0, n) as u32;
                clients[rng.range(0, n)].migrate(g, to).unwrap();
            }
            // After refresh every client agrees with the home table.
            for &g in &gids {
                let truth = agas.resolve_home(g).unwrap();
                for c in &clients {
                    assert_eq!(c.refresh(g).unwrap(), truth);
                }
            }
        });
    }
}
