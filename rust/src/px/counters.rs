//! Performance counters — the paper's "generic monitoring framework"
//! (Fig 1) that enables dynamic and intrinsic system and load estimates.
//!
//! Counters are plain relaxed atomics grouped per locality and aggregated
//! by the runtime. They are cheap enough to leave enabled on the hot path
//! (one relaxed `fetch_add` per event); the Fig 9 overhead bench measures
//! their cost as part of thread-management overhead, exactly as HPX does.

use crate::util::CachePadded;
use std::sync::atomic::{AtomicU64, Ordering};

/// One monotonically increasing event counter.
#[derive(Default)]
pub struct Counter(CachePadded<AtomicU64>);

impl Counter {
    /// Increment by one.
    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Increment by `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Record a maximum (monotone; used for high-water marks).
    pub fn max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }
}

/// Counter set for one locality's runtime services.
///
/// Field names follow the paper's taxonomy of SLOW factors: starvation is
/// visible through `steals`/`parked_waits`, latency through parcel
/// round-trips, overhead through `threads_spawned` × per-thread cost, and
/// contention through `queue_contended`.
#[derive(Default)]
pub struct Counters {
    /// PX-threads created (locally spawned + parcel-instantiated).
    pub threads_spawned: Counter,
    /// PX-threads that ran to completion.
    pub threads_completed: Counter,
    /// PX-threads created in direct response to an incoming parcel.
    pub threads_from_parcels: Counter,
    /// Continuations registered on LCOs (suspension events).
    pub suspensions: Counter,
    /// Continuations resumed by LCO triggers.
    pub resumptions: Counter,
    /// Work-stealing events (local-priority policy only).
    pub steals: Counter,
    /// Times a worker found every queue empty and parked.
    pub parked_waits: Counter,
    /// Lock acquisitions on a scheduling queue that had to contend.
    /// On the lock-free schedulers the only lock left is the injector's
    /// overflow spillover, so this stays ~0 by construction.
    pub queue_contended: Counter,
    /// CAS retries on lock-free scheduling queues (a cursor race lost to
    /// another core). The lock-free analogue of `queue_contended`.
    pub queue_cas_retries: Counter,
    /// High-water mark of any scheduling queue length.
    pub queue_hwm: Counter,
    /// Parcels sent to a remote locality.
    pub parcels_sent: Counter,
    /// Parcels received and decoded.
    pub parcels_received: Counter,
    /// Parcels re-sent by the action manager because a stale AGAS cache
    /// routed them to a locality that no longer hosts the object (the
    /// migration hop-forwarding path).
    pub parcels_forwarded: Counter,
    /// Total serialized parcel bytes sent.
    pub parcel_bytes: Counter,
    /// AGAS lookups answered from the local cache.
    pub agas_cache_hits: Counter,
    /// AGAS lookups that went to the home table.
    pub agas_cache_misses: Counter,
    /// Objects migrated between localities.
    pub migrations: Counter,
    /// LCO set/trigger events (future set_value, dataflow input, ...).
    pub lco_triggers: Counter,
    /// XLA executable invocations (the PJRT hot path).
    pub xla_calls: Counter,
    /// Nanoseconds spent inside `ComputeBackend::step_exact` on this
    /// locality — the pure kernel cost, excluding assembly/scheduling, so
    /// a faster backend (DESIGN.md §10) is visible next to `amr_pushes`
    /// and the CostModel's per-block EWMA.
    pub kernel_ns_total: Counter,
    /// AMR dataflow inputs delivered into a task table — same-locality
    /// `Arc` refcount bumps plus decoded remote arrivals (a remote input
    /// counts once here, at the receiver, and once in
    /// `amr_remote_pushes`, at the sender).
    pub amr_pushes: Counter,
    /// AMR dataflow inputs whose producer and consumer live on different
    /// localities: the fragment was serialized into a parcel and crossed
    /// the wire. Counted at the sender; these are wire transfers, not
    /// deep copies on the local push path (`payload_deep_copies` stays 0).
    pub amr_remote_pushes: Counter,
    /// Deep copies of fragment payloads on the *same-locality* dataflow
    /// push path. Contract: stays 0 — the zero-copy regression tripwire.
    /// Any future code that must deep-copy a payload on the local push
    /// path bumps this. (Remote deliveries serialize by necessity and are
    /// accounted under `amr_remote_pushes`/`parcel_bytes` instead.)
    pub payload_deep_copies: Counter,
    /// Remote AMR pushes that travelled inside a coalesced
    /// `ACT_AMR_PUSH_BATCH` parcel instead of paying their own wire
    /// latency (counted at the sender; a subset of `amr_remote_pushes`).
    /// Zero when ghost batching is disabled.
    pub amr_batched_pushes: Counter,
    /// Serialized AMR fragment payload bytes whose producer and consumer
    /// lived on *different* localities at send time — the cut of the
    /// block traffic graph under the current placement, payload only
    /// (parcel/batch envelope headers are excluded; see `parcel_bytes`
    /// for whole-wire accounting). The metric `PlacementPolicy::Wire`
    /// exists to shrink (DESIGN.md §12); counted at the sender on both
    /// the batched and per-fragment push paths.
    pub amr_cut_bytes: Counter,
    /// Epoch boundaries at which the adaptive placement policy moved at
    /// least one block relative to where it ended the previous epoch —
    /// the coordinator's cost-feedback loop firing (DESIGN.md §7).
    pub placement_rebalances: Counter,
    /// AMR block-step tasks whose inputs were completed by an
    /// `ACT_AMR_PUSH_BATCH` arrival and that were drained straight into
    /// one `spawn_batch` call — the whole batch publishes a single
    /// worker wake instead of one per completed task (DESIGN.md §8).
    pub amr_batch_spawns: Counter,
    /// Parcels that arrived at a gracefully detached port and were
    /// redirected to the anchor locality (the hop-forward fallback).
    /// Folded in from `SimNet::bounced()` by `counters_total`.
    pub bounced: Counter,
    /// Parcels whose destination port was gone with no anchor fallback —
    /// quarantined arrivals held for replay plus true discards. Folded in
    /// from `SimNet::dead_letters()` by `counters_total`; ends at 0 after
    /// a successful recovery replay.
    pub dead_letters: Counter,
    /// Dead-lettered parcels re-resolved against post-recovery AGAS and
    /// re-sent by the recovery subsystem (DESIGN.md §9).
    pub parcels_replayed: Counter,
    /// AGAS Block residents reconstructed onto survivors from the
    /// per-epoch checkpoint after an unplanned locality death.
    pub blocks_recovered: Counter,
    /// Heartbeat deadlines a member missed before the failure detector
    /// declared it dead (K consecutive misses trigger recovery).
    pub heartbeats_missed: Counter,
}

/// A plain snapshot of all counters, for diffing across a run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    pub threads_spawned: u64,
    pub threads_completed: u64,
    pub threads_from_parcels: u64,
    pub suspensions: u64,
    pub resumptions: u64,
    pub steals: u64,
    pub parked_waits: u64,
    pub queue_contended: u64,
    pub queue_cas_retries: u64,
    pub queue_hwm: u64,
    pub parcels_sent: u64,
    pub parcels_received: u64,
    pub parcels_forwarded: u64,
    pub parcel_bytes: u64,
    pub agas_cache_hits: u64,
    pub agas_cache_misses: u64,
    pub migrations: u64,
    pub lco_triggers: u64,
    pub xla_calls: u64,
    pub kernel_ns_total: u64,
    pub amr_pushes: u64,
    pub amr_remote_pushes: u64,
    pub payload_deep_copies: u64,
    pub amr_batched_pushes: u64,
    pub amr_cut_bytes: u64,
    pub placement_rebalances: u64,
    pub amr_batch_spawns: u64,
    pub bounced: u64,
    pub dead_letters: u64,
    pub parcels_replayed: u64,
    pub blocks_recovered: u64,
    pub heartbeats_missed: u64,
}

impl Counters {
    /// Capture the current values.
    pub fn snapshot(&self) -> CounterSnapshot {
        CounterSnapshot {
            threads_spawned: self.threads_spawned.get(),
            threads_completed: self.threads_completed.get(),
            threads_from_parcels: self.threads_from_parcels.get(),
            suspensions: self.suspensions.get(),
            resumptions: self.resumptions.get(),
            steals: self.steals.get(),
            parked_waits: self.parked_waits.get(),
            queue_contended: self.queue_contended.get(),
            queue_cas_retries: self.queue_cas_retries.get(),
            queue_hwm: self.queue_hwm.get(),
            parcels_sent: self.parcels_sent.get(),
            parcels_received: self.parcels_received.get(),
            parcels_forwarded: self.parcels_forwarded.get(),
            parcel_bytes: self.parcel_bytes.get(),
            agas_cache_hits: self.agas_cache_hits.get(),
            agas_cache_misses: self.agas_cache_misses.get(),
            migrations: self.migrations.get(),
            lco_triggers: self.lco_triggers.get(),
            xla_calls: self.xla_calls.get(),
            kernel_ns_total: self.kernel_ns_total.get(),
            amr_pushes: self.amr_pushes.get(),
            amr_remote_pushes: self.amr_remote_pushes.get(),
            payload_deep_copies: self.payload_deep_copies.get(),
            amr_batched_pushes: self.amr_batched_pushes.get(),
            amr_cut_bytes: self.amr_cut_bytes.get(),
            placement_rebalances: self.placement_rebalances.get(),
            amr_batch_spawns: self.amr_batch_spawns.get(),
            bounced: self.bounced.get(),
            dead_letters: self.dead_letters.get(),
            parcels_replayed: self.parcels_replayed.get(),
            blocks_recovered: self.blocks_recovered.get(),
            heartbeats_missed: self.heartbeats_missed.get(),
        }
    }
}

impl CounterSnapshot {
    /// Fold another locality's snapshot into this one (runtime-wide
    /// totals): every event counter sums, high-water marks take the max.
    /// Lives next to the field list so a new counter cannot be forgotten
    /// by the aggregation the way a by-hand sum in `runtime.rs` once
    /// dropped `amr_batched_pushes`/`placement_rebalances`.
    pub fn absorb(&mut self, s: &CounterSnapshot) {
        self.threads_spawned += s.threads_spawned;
        self.threads_completed += s.threads_completed;
        self.threads_from_parcels += s.threads_from_parcels;
        self.suspensions += s.suspensions;
        self.resumptions += s.resumptions;
        self.steals += s.steals;
        self.parked_waits += s.parked_waits;
        self.queue_contended += s.queue_contended;
        self.queue_cas_retries += s.queue_cas_retries;
        self.queue_hwm = self.queue_hwm.max(s.queue_hwm);
        self.parcels_sent += s.parcels_sent;
        self.parcels_received += s.parcels_received;
        self.parcels_forwarded += s.parcels_forwarded;
        self.parcel_bytes += s.parcel_bytes;
        self.agas_cache_hits += s.agas_cache_hits;
        self.agas_cache_misses += s.agas_cache_misses;
        self.migrations += s.migrations;
        self.lco_triggers += s.lco_triggers;
        self.xla_calls += s.xla_calls;
        self.kernel_ns_total += s.kernel_ns_total;
        self.amr_pushes += s.amr_pushes;
        self.amr_remote_pushes += s.amr_remote_pushes;
        self.payload_deep_copies += s.payload_deep_copies;
        self.amr_batched_pushes += s.amr_batched_pushes;
        self.amr_cut_bytes += s.amr_cut_bytes;
        self.placement_rebalances += s.placement_rebalances;
        self.amr_batch_spawns += s.amr_batch_spawns;
        self.bounced += s.bounced;
        self.dead_letters += s.dead_letters;
        self.parcels_replayed += s.parcels_replayed;
        self.blocks_recovered += s.blocks_recovered;
        self.heartbeats_missed += s.heartbeats_missed;
    }

    /// Event deltas between two snapshots (self - earlier).
    pub fn since(&self, earlier: &CounterSnapshot) -> CounterSnapshot {
        CounterSnapshot {
            threads_spawned: self.threads_spawned - earlier.threads_spawned,
            threads_completed: self.threads_completed - earlier.threads_completed,
            threads_from_parcels: self.threads_from_parcels - earlier.threads_from_parcels,
            suspensions: self.suspensions - earlier.suspensions,
            resumptions: self.resumptions - earlier.resumptions,
            steals: self.steals - earlier.steals,
            parked_waits: self.parked_waits - earlier.parked_waits,
            queue_contended: self.queue_contended - earlier.queue_contended,
            queue_cas_retries: self.queue_cas_retries - earlier.queue_cas_retries,
            queue_hwm: self.queue_hwm.max(earlier.queue_hwm),
            parcels_sent: self.parcels_sent - earlier.parcels_sent,
            parcels_received: self.parcels_received - earlier.parcels_received,
            parcels_forwarded: self.parcels_forwarded - earlier.parcels_forwarded,
            parcel_bytes: self.parcel_bytes - earlier.parcel_bytes,
            agas_cache_hits: self.agas_cache_hits - earlier.agas_cache_hits,
            agas_cache_misses: self.agas_cache_misses - earlier.agas_cache_misses,
            migrations: self.migrations - earlier.migrations,
            lco_triggers: self.lco_triggers - earlier.lco_triggers,
            xla_calls: self.xla_calls - earlier.xla_calls,
            kernel_ns_total: self.kernel_ns_total - earlier.kernel_ns_total,
            amr_pushes: self.amr_pushes - earlier.amr_pushes,
            amr_remote_pushes: self.amr_remote_pushes - earlier.amr_remote_pushes,
            payload_deep_copies: self.payload_deep_copies - earlier.payload_deep_copies,
            amr_batched_pushes: self.amr_batched_pushes - earlier.amr_batched_pushes,
            amr_cut_bytes: self.amr_cut_bytes - earlier.amr_cut_bytes,
            placement_rebalances: self.placement_rebalances - earlier.placement_rebalances,
            amr_batch_spawns: self.amr_batch_spawns - earlier.amr_batch_spawns,
            bounced: self.bounced - earlier.bounced,
            // Non-monotone by design: a recovery replay drains captured
            // dead letters back out of the tally, so a later snapshot can
            // be smaller than an earlier one.
            dead_letters: self.dead_letters.saturating_sub(earlier.dead_letters),
            parcels_replayed: self.parcels_replayed - earlier.parcels_replayed,
            blocks_recovered: self.blocks_recovered - earlier.blocks_recovered,
            heartbeats_missed: self.heartbeats_missed - earlier.heartbeats_missed,
        }
    }

    /// Render as aligned `name value` lines for logs and reports.
    pub fn render(&self) -> String {
        let rows = [
            ("threads_spawned", self.threads_spawned),
            ("threads_completed", self.threads_completed),
            ("threads_from_parcels", self.threads_from_parcels),
            ("suspensions", self.suspensions),
            ("resumptions", self.resumptions),
            ("steals", self.steals),
            ("parked_waits", self.parked_waits),
            ("queue_contended", self.queue_contended),
            ("queue_cas_retries", self.queue_cas_retries),
            ("queue_hwm", self.queue_hwm),
            ("parcels_sent", self.parcels_sent),
            ("parcels_received", self.parcels_received),
            ("parcels_forwarded", self.parcels_forwarded),
            ("parcel_bytes", self.parcel_bytes),
            ("agas_cache_hits", self.agas_cache_hits),
            ("agas_cache_misses", self.agas_cache_misses),
            ("migrations", self.migrations),
            ("lco_triggers", self.lco_triggers),
            ("xla_calls", self.xla_calls),
            ("kernel_ns_total", self.kernel_ns_total),
            ("amr_pushes", self.amr_pushes),
            ("amr_remote_pushes", self.amr_remote_pushes),
            ("payload_deep_copies", self.payload_deep_copies),
            ("amr_batched_pushes", self.amr_batched_pushes),
            ("amr_cut_bytes", self.amr_cut_bytes),
            ("placement_rebalances", self.placement_rebalances),
            ("amr_batch_spawns", self.amr_batch_spawns),
            ("bounced", self.bounced),
            ("dead_letters", self.dead_letters),
            ("parcels_replayed", self.parcels_replayed),
            ("blocks_recovered", self.blocks_recovered),
            ("heartbeats_missed", self.heartbeats_missed),
        ];
        let mut out = String::new();
        for (k, v) in rows {
            out.push_str(&format!("{k:<22} {v}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn inc_add_get() {
        let c = Counter::default();
        c.inc();
        c.add(10);
        assert_eq!(c.get(), 11);
    }

    #[test]
    fn max_is_monotone() {
        let c = Counter::default();
        c.max(5);
        c.max(3);
        assert_eq!(c.get(), 5);
        c.max(9);
        assert_eq!(c.get(), 9);
    }

    #[test]
    fn snapshot_diff() {
        let cs = Counters::default();
        cs.threads_spawned.add(5);
        let a = cs.snapshot();
        cs.threads_spawned.add(7);
        cs.steals.inc();
        let b = cs.snapshot();
        let d = b.since(&a);
        assert_eq!(d.threads_spawned, 7);
        assert_eq!(d.steals, 1);
    }

    #[test]
    fn counters_are_thread_safe() {
        let cs = Arc::new(Counters::default());
        let mut handles = vec![];
        for _ in 0..8 {
            let cs = cs.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..10_000 {
                    cs.threads_spawned.inc();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(cs.threads_spawned.get(), 80_000);
    }

    #[test]
    fn render_contains_every_field() {
        let s = Counters::default().snapshot().render();
        assert!(s.contains("threads_spawned") && s.contains("xla_calls"));
        assert!(s.contains("amr_batch_spawns"));
        assert!(s.contains("amr_cut_bytes"));
        assert!(s.contains("dead_letters") && s.contains("parcels_replayed"));
        assert!(s.contains("blocks_recovered") && s.contains("heartbeats_missed"));
        assert!(s.contains("bounced"));
        assert!(s.contains("kernel_ns_total"));
    }

    #[test]
    fn absorb_sums_events_and_maxes_hwm() {
        let a = Counters::default();
        a.amr_batched_pushes.add(3);
        a.amr_cut_bytes.add(400);
        a.placement_rebalances.inc();
        a.amr_batch_spawns.add(2);
        a.queue_hwm.max(5);
        a.parcels_replayed.add(2);
        a.blocks_recovered.inc();
        a.kernel_ns_total.add(100);
        let b = Counters::default();
        b.kernel_ns_total.add(250);
        b.amr_batched_pushes.add(4);
        b.amr_cut_bytes.add(100);
        b.amr_batch_spawns.add(1);
        b.queue_hwm.max(9);
        b.parcels_replayed.add(3);
        b.heartbeats_missed.add(5);
        b.dead_letters.inc();
        b.bounced.add(2);
        let mut total = a.snapshot();
        total.absorb(&b.snapshot());
        assert_eq!(total.amr_batched_pushes, 7);
        assert_eq!(total.amr_cut_bytes, 500);
        assert_eq!(total.placement_rebalances, 1);
        assert_eq!(total.amr_batch_spawns, 3);
        assert_eq!(total.queue_hwm, 9);
        assert_eq!(total.parcels_replayed, 5);
        assert_eq!(total.blocks_recovered, 1);
        assert_eq!(total.heartbeats_missed, 5);
        assert_eq!(total.dead_letters, 1);
        assert_eq!(total.bounced, 2);
        assert_eq!(total.kernel_ns_total, 350);
    }
}
