//! Performance counters — the paper's "generic monitoring framework"
//! (Fig 1) that enables dynamic and intrinsic system and load estimates.
//!
//! Counters are plain relaxed atomics grouped per locality and aggregated
//! by the runtime. They are cheap enough to leave enabled on the hot path
//! (one relaxed `fetch_add` per event); the Fig 9 overhead bench measures
//! their cost as part of thread-management overhead, exactly as HPX does.
//!
//! The field list lives in exactly one place: the `for_each_counter!`
//! registry below. `Counters`, [`CounterSnapshot`], `snapshot`, `absorb`,
//! `since` and `render` are all generated from it, so a new counter cannot
//! be forgotten by any of them — the by-hand quadruplication this replaces
//! once let `counters_total` silently drop two fields. Each entry carries a
//! *kind* that fixes its aggregation semantics:
//!
//! * `event` — monotone event count: `absorb` sums, `since` subtracts.
//! * `hwm` — high-water mark: `absorb` takes the max; `since` reports the
//!   **later** snapshot's mark (a mark over a window is not a delta — the
//!   old `max(self, earlier)` answer was simply wrong when the mark had
//!   been reached before the window opened), and `render` labels it so.
//! * `level` — non-monotone level (e.g. `dead_letters`, which a recovery
//!   replay drains back down): `absorb` sums, `since` saturates at zero
//!   instead of underflowing, and `render` labels it.

use crate::util::CachePadded;
use std::sync::atomic::{AtomicU64, Ordering};

/// One monotonically increasing event counter.
#[derive(Default)]
pub struct Counter(CachePadded<AtomicU64>);

impl Counter {
    /// Increment by one.
    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Increment by `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Record a maximum (monotone; used for high-water marks).
    pub fn max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }
}

/// The single registry of every counter: `(name, kind, doc)`.
///
/// Invoked with a callback macro that receives the whole list; all four
/// generated items (struct fields, snapshot, fold, render) expand from
/// this one list, in this order.
macro_rules! for_each_counter {
    ($with:ident) => {
        $with! {
            (threads_spawned, event,
             "PX-threads created (locally spawned + parcel-instantiated)."),
            (threads_completed, event,
             "PX-threads that ran to completion."),
            (threads_from_parcels, event,
             "PX-threads created in direct response to an incoming parcel."),
            (suspensions, event,
             "Continuations registered on LCOs (suspension events)."),
            (resumptions, event,
             "Continuations resumed by LCO triggers."),
            (steals, event,
             "Work-stealing events (local-priority policy only)."),
            (parked_waits, event,
             "Times a worker found every queue empty and parked."),
            (queue_contended, event,
             "Lock acquisitions on a scheduling queue that had to contend. \
              On the lock-free schedulers the only lock left is the \
              injector's overflow spillover, so this stays ~0 by \
              construction."),
            (queue_cas_retries, event,
             "CAS retries on lock-free scheduling queues (a cursor race \
              lost to another core). The lock-free analogue of \
              `queue_contended`."),
            (queue_hwm, hwm,
             "High-water mark of any scheduling queue length."),
            (parcels_sent, event,
             "Parcels sent to a remote locality."),
            (parcels_received, event,
             "Parcels received and decoded."),
            (parcels_forwarded, event,
             "Parcels re-sent by the action manager because a stale AGAS \
              cache routed them to a locality that no longer hosts the \
              object (the migration hop-forwarding path)."),
            (parcel_bytes, event,
             "Total serialized parcel bytes sent."),
            (agas_cache_hits, event,
             "AGAS lookups answered from the local cache."),
            (agas_cache_misses, event,
             "AGAS lookups that went to the home table."),
            (migrations, event,
             "Objects migrated between localities."),
            (lco_triggers, event,
             "LCO set/trigger events (future set_value, dataflow input, ...)."),
            (xla_calls, event,
             "XLA executable invocations (the PJRT hot path)."),
            (kernel_ns_total, event,
             "Nanoseconds spent inside `ComputeBackend::step_exact` on this \
              locality — the pure kernel cost, excluding assembly/\
              scheduling, so a faster backend (DESIGN.md §10) is visible \
              next to `amr_pushes` and the CostModel's per-block EWMA."),
            (amr_pushes, event,
             "AMR dataflow inputs delivered into a task table — \
              same-locality `Arc` refcount bumps plus decoded remote \
              arrivals (a remote input counts once here, at the receiver, \
              and once in `amr_remote_pushes`, at the sender)."),
            (amr_remote_pushes, event,
             "AMR dataflow inputs whose producer and consumer live on \
              different localities: the fragment was serialized into a \
              parcel and crossed the wire. Counted at the sender; these \
              are wire transfers, not deep copies on the local push path \
              (`payload_deep_copies` stays 0)."),
            (payload_deep_copies, event,
             "Deep copies of fragment payloads on the *same-locality* \
              dataflow push path. Contract: stays 0 — the zero-copy \
              regression tripwire. Any future code that must deep-copy a \
              payload on the local push path bumps this. (Remote \
              deliveries serialize by necessity and are accounted under \
              `amr_remote_pushes`/`parcel_bytes` instead.)"),
            (amr_batched_pushes, event,
             "Remote AMR pushes that travelled inside a coalesced \
              `ACT_AMR_PUSH_BATCH` parcel instead of paying their own wire \
              latency (counted at the sender; a subset of \
              `amr_remote_pushes`). Zero when ghost batching is disabled."),
            (amr_cut_bytes, event,
             "Serialized AMR fragment payload bytes whose producer and \
              consumer lived on *different* localities at send time — the \
              cut of the block traffic graph under the current placement, \
              payload only (parcel/batch envelope headers are excluded; \
              see `parcel_bytes` for whole-wire accounting). The metric \
              `PlacementPolicy::Wire` exists to shrink (DESIGN.md §12); \
              counted at the sender on both the batched and per-fragment \
              push paths."),
            (placement_rebalances, event,
             "Epoch boundaries at which the adaptive placement policy \
              moved at least one block relative to where it ended the \
              previous epoch — the coordinator's cost-feedback loop firing \
              (DESIGN.md §7)."),
            (amr_batch_spawns, event,
             "AMR block-step tasks whose inputs were completed by an \
              `ACT_AMR_PUSH_BATCH` arrival and that were drained straight \
              into one `spawn_batch` call — the whole batch publishes a \
              single worker wake instead of one per completed task \
              (DESIGN.md §8)."),
            (bounced, event,
             "Parcels that arrived at a gracefully detached port and were \
              redirected to the anchor locality (the hop-forward \
              fallback). Folded in from `SimNet::bounced()` by \
              `counters_total`."),
            (dead_letters, level,
             "Parcels whose destination port was gone with no anchor \
              fallback — quarantined arrivals held for replay plus true \
              discards. Folded in from `SimNet::dead_letters()` by \
              `counters_total`; ends at 0 after a successful recovery \
              replay, so this is a *level*, not a monotone count — a \
              later snapshot can legitimately be smaller than an earlier \
              one, and `since` saturates at zero instead of underflowing."),
            (parcels_replayed, event,
             "Dead-lettered parcels re-resolved against post-recovery AGAS \
              and re-sent by the recovery subsystem (DESIGN.md §9)."),
            (blocks_recovered, event,
             "AGAS Block residents reconstructed onto survivors from the \
              per-epoch checkpoint after an unplanned locality death."),
            (heartbeats_missed, event,
             "Heartbeat deadlines a member missed before the failure \
              detector declared it dead (K consecutive misses trigger \
              recovery)."),
        }
    };
}

/// `absorb` semantics per counter kind (runtime-wide totals).
macro_rules! absorb_field {
    (event, $mine:expr, $theirs:expr) => {
        $mine += $theirs
    };
    (hwm, $mine:expr, $theirs:expr) => {
        $mine = $mine.max($theirs)
    };
    (level, $mine:expr, $theirs:expr) => {
        $mine += $theirs
    };
}

/// `since` semantics per counter kind (windowed deltas).
macro_rules! since_field {
    (event, $later:expr, $earlier:expr) => {
        $later - $earlier
    };
    // A high-water mark over a window is the later snapshot's mark, not a
    // difference of marks (and not `max` of the two — the mark may predate
    // the window entirely; the reader just wants "how high did it get").
    (hwm, $later:expr, $earlier:expr) => {
        $later
    };
    // Non-monotone level: a recovery replay drains the tally back down, so
    // the windowed view saturates at zero instead of underflowing.
    (level, $later:expr, $earlier:expr) => {
        $later.saturating_sub($earlier)
    };
}

/// Suffix `render` appends so a reader of the delta dump knows which rows
/// are not plain event deltas.
macro_rules! render_note {
    (event) => {
        ""
    };
    (hwm) => {
        "  [high-water mark of the window's later snapshot, not a delta]"
    };
    (level) => {
        "  [level, non-monotone: recovery replay drains it]"
    };
}

macro_rules! define_counters {
    ($( ($name:ident, $kind:ident, $doc:expr) ),+ $(,)?) => {
        /// Counter set for one locality's runtime services.
        ///
        /// Field names follow the paper's taxonomy of SLOW factors:
        /// starvation is visible through `steals`/`parked_waits`, latency
        /// through parcel round-trips, overhead through `threads_spawned`
        /// × per-thread cost, and contention through `queue_contended`.
        #[derive(Default)]
        pub struct Counters {
            $( #[doc = $doc] pub $name: Counter, )+
        }

        /// A plain snapshot of all counters, for diffing across a run.
        #[derive(Debug, Clone, Default, PartialEq, Eq)]
        pub struct CounterSnapshot {
            $( #[doc = $doc] pub $name: u64, )+
        }

        impl Counters {
            /// Capture the current values.
            pub fn snapshot(&self) -> CounterSnapshot {
                CounterSnapshot {
                    $( $name: self.$name.get(), )+
                }
            }
        }

        impl CounterSnapshot {
            /// Number of counters in the registry — `render()` emits
            /// exactly this many rows, and the test below pins it.
            pub const FIELD_COUNT: usize = [$(stringify!($name)),+].len();

            /// Fold another locality's snapshot into this one
            /// (runtime-wide totals): every event counter sums,
            /// high-water marks take the max. Generated from the same
            /// registry as the field list so a new counter cannot be
            /// forgotten by the aggregation the way a by-hand sum in
            /// `runtime.rs` once dropped
            /// `amr_batched_pushes`/`placement_rebalances`.
            pub fn absorb(&mut self, s: &CounterSnapshot) {
                $( absorb_field!($kind, self.$name, s.$name); )+
            }

            /// Event deltas between two snapshots (self - earlier).
            /// High-water marks report the later snapshot's mark; levels
            /// saturate at zero (see the module docs).
            pub fn since(&self, earlier: &CounterSnapshot) -> CounterSnapshot {
                CounterSnapshot {
                    $( $name: since_field!($kind, self.$name, earlier.$name), )+
                }
            }

            /// Render as aligned `name value` lines for logs and reports.
            /// Rows whose semantics differ from a plain event delta
            /// (high-water marks, non-monotone levels) carry a bracketed
            /// note.
            pub fn render(&self) -> String {
                let mut out = String::new();
                $(
                    out.push_str(&format!(
                        "{:<22} {}{}\n",
                        stringify!($name),
                        self.$name,
                        render_note!($kind)
                    ));
                )+
                out
            }
        }
    };
}

for_each_counter!(define_counters);

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn inc_add_get() {
        let c = Counter::default();
        c.inc();
        c.add(10);
        assert_eq!(c.get(), 11);
    }

    #[test]
    fn max_is_monotone() {
        let c = Counter::default();
        c.max(5);
        c.max(3);
        assert_eq!(c.get(), 5);
        c.max(9);
        assert_eq!(c.get(), 9);
    }

    #[test]
    fn snapshot_diff() {
        let cs = Counters::default();
        cs.threads_spawned.add(5);
        let a = cs.snapshot();
        cs.threads_spawned.add(7);
        cs.steals.inc();
        let b = cs.snapshot();
        let d = b.since(&a);
        assert_eq!(d.threads_spawned, 7);
        assert_eq!(d.steals, 1);
    }

    #[test]
    fn counters_are_thread_safe() {
        let cs = Arc::new(Counters::default());
        let mut handles = vec![];
        for _ in 0..8 {
            let cs = cs.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..10_000 {
                    cs.threads_spawned.inc();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(cs.threads_spawned.get(), 80_000);
    }

    #[test]
    fn render_contains_every_field() {
        let s = Counters::default().snapshot().render();
        assert!(s.contains("threads_spawned") && s.contains("xla_calls"));
        assert!(s.contains("amr_batch_spawns"));
        assert!(s.contains("amr_cut_bytes"));
        assert!(s.contains("dead_letters") && s.contains("parcels_replayed"));
        assert!(s.contains("blocks_recovered") && s.contains("heartbeats_missed"));
        assert!(s.contains("bounced"));
        assert!(s.contains("kernel_ns_total"));
    }

    /// The registry is the single source of truth: `render()` must emit
    /// one row per field, no more, no fewer. This is the regression guard
    /// for the drift that once let `counters_total` drop two fields.
    #[test]
    fn render_row_count_matches_field_count() {
        let s = Counters::default().snapshot().render();
        assert_eq!(s.lines().count(), CounterSnapshot::FIELD_COUNT);
        // Sanity: the registry currently holds all 32 counters.
        assert_eq!(CounterSnapshot::FIELD_COUNT, 32);
    }

    /// A high-water mark over a window reports the *later* snapshot's
    /// mark — not `max(later, earlier)` (the pre-registry bug: if the
    /// mark was reached before the window opened, the old answer claimed
    /// the window hit it too).
    #[test]
    fn since_reports_later_hwm_mark() {
        let cs = Counters::default();
        cs.queue_hwm.max(50);
        let a = cs.snapshot();
        let b = cs.snapshot();
        // No queue activity inside the window: the window's mark is the
        // later snapshot's mark (still 50 — the counter is process-wide),
        // and critically NOT inflated above it.
        assert_eq!(b.since(&a).queue_hwm, b.queue_hwm);
        assert_eq!(b.since(&a).queue_hwm, 50);
        // The rendered dump labels the row as a mark, not a delta.
        assert!(b.since(&a).render().contains("high-water mark"));
    }

    /// `dead_letters` is a level, not a monotone count: a recovery replay
    /// drains it, so a later snapshot can be smaller and `since` must
    /// saturate rather than underflow.
    #[test]
    fn since_saturates_nonmonotone_dead_letters() {
        let a = CounterSnapshot { dead_letters: 7, ..Default::default() };
        let b = CounterSnapshot { dead_letters: 2, ..Default::default() };
        assert_eq!(b.since(&a).dead_letters, 0);
    }

    #[test]
    fn absorb_sums_events_and_maxes_hwm() {
        let a = Counters::default();
        a.amr_batched_pushes.add(3);
        a.amr_cut_bytes.add(400);
        a.placement_rebalances.inc();
        a.amr_batch_spawns.add(2);
        a.queue_hwm.max(5);
        a.parcels_replayed.add(2);
        a.blocks_recovered.inc();
        a.kernel_ns_total.add(100);
        let b = Counters::default();
        b.kernel_ns_total.add(250);
        b.amr_batched_pushes.add(4);
        b.amr_cut_bytes.add(100);
        b.amr_batch_spawns.add(1);
        b.queue_hwm.max(9);
        b.parcels_replayed.add(3);
        b.heartbeats_missed.add(5);
        b.dead_letters.inc();
        b.bounced.add(2);
        let mut total = a.snapshot();
        total.absorb(&b.snapshot());
        assert_eq!(total.amr_batched_pushes, 7);
        assert_eq!(total.amr_cut_bytes, 500);
        assert_eq!(total.placement_rebalances, 1);
        assert_eq!(total.amr_batch_spawns, 3);
        assert_eq!(total.queue_hwm, 9);
        assert_eq!(total.parcels_replayed, 5);
        assert_eq!(total.blocks_recovered, 1);
        assert_eq!(total.heartbeats_missed, 5);
        assert_eq!(total.dead_letters, 1);
        assert_eq!(total.bounced, 2);
        assert_eq!(total.kernel_ns_total, 350);
    }
}
