//! `px-amr` — launcher for the ParalleX AMR reproduction.
//!
//! Subcommands:
//!   run        evolve the semilinear wave with barrier-free AMR (e2e driver)
//!   fig2..fig9 regenerate the paper's figures (see DESIGN.md §5)
//!   fpga       §V thread-queue offload study
//!   dist       distributed AMR strong scaling (1->8 localities), BENCH_2.json
//!              (--elastic <script> runs a scripted membership-change epoch)
//!   bench3     ghost batching + adaptive placement study, BENCH_3.json
//!   bench4     elastic localities study (steady/shrink/grow), BENCH_4.json
//!   bench5     crash tolerance study (steady/checkpointed/kill), BENCH_5.json
//!   bench6     kernel fast path study (native/fused/simd), BENCH_6.json
//!   bench7     deterministic replay study (dataflow vs barrier), BENCH_7.json
//!   bench8     wire-aware placement study (traffic-refined packing under
//!              regridding + elastic membership + strong scaling), BENCH_8.json
//!   bench9     flight-recorder causal tracing study (critical path vs total
//!              work, tracing tax), BENCH_9.json
//!   info       print runtime/topology/artifact information
//!
//! Common options for `run`:
//!   --n0 N --levels L --steps S --granularity G --workers W
//!   --backend native|fused|simd|xla --scheduler local|global --barrier
//!   --epochs E (regrid between epochs) --amplitude A --deadline-ms MS
//!   --localities K (distributed localities with a simulated wire)
//!   --placement slabs|weighted|adaptive|wire (block -> locality policy;
//!     adaptive feeds each epoch's observed costs into the next map, wire
//!     additionally folds observed parcel traffic into the packing
//!     objective, tuned by --wire-alpha)
//!   --trace out.json (record the flight recorder and write the run as
//!     Perfetto-loadable Chrome trace-event JSON; also on `dist`)

// Same style-lint opt-outs as the library crate (see lib.rs): CI runs
// `cargo clippy -- -D warnings` over both.
#![allow(clippy::too_many_arguments, clippy::needless_range_loop)]

use std::sync::Arc;

use parallex::amr::backend::{make_backend, BackendKind};
use parallex::amr::dataflow_driver::{
    initial_block_states, run_epoch_adaptive, run_epoch_placed, run_epoch_wire, AmrConfig,
};
use parallex::amr::engine::EpochPlan;
use parallex::coordinator::{CostModel, DistAmrOpts, PlacementPolicy, TrafficModel};
use parallex::amr::mesh::MeshConfig;
use parallex::amr::physics::energy_norm;
use parallex::amr::regrid::{initial_hierarchy, regrid_hierarchy, remap, Composite, RegridConfig};
use parallex::bench;
use parallex::cli::Args;
use parallex::metrics::fmt_dur;
use parallex::px::net::NetModel;
use parallex::px::runtime::{PxConfig, PxRuntime, SchedPolicyKind};
use parallex::px::trace;

fn main() {
    // Quiet the PJRT CPU client's info logging unless the user overrides.
    if std::env::var("TF_CPP_MIN_LOG_LEVEL").is_err() {
        std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
    }
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("px-amr: {e}");
            std::process::exit(2);
        }
    };
    let scale = bench::Scale::from_env();
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".to_string());
    let result = match sub.as_str() {
        "run" => cmd_run(&args),
        "info" => cmd_info(),
        "fig2" => {
            print!("{}", bench::fig2_mesh());
            Ok(())
        }
        "fig3" => {
            print!("{}", bench::fig3_granularity(scale));
            Ok(())
        }
        "fig5" => {
            print!("{}", bench::fig5_cone(scale));
            Ok(())
        }
        "fig6" => {
            print!("{}", bench::fig6_barrier(scale));
            Ok(())
        }
        "fig7" => {
            print!("{}", bench::fig7_scaling(scale));
            Ok(())
        }
        "fig8" => {
            print!("{}", bench::fig8_wallclock(scale));
            Ok(())
        }
        "fig9" => {
            print!("{}", bench::fig9_thread_overhead(scale));
            Ok(())
        }
        "fpga" => {
            print!("{}", bench::fpga_fib_table(scale));
            Ok(())
        }
        "dist" => cmd_dist(&args, scale),
        "bench3" => cmd_bench_artifact(&args, scale, "BENCH_3.json", bench::write_bench3_json),
        "bench4" => cmd_bench_artifact(&args, scale, "BENCH_4.json", bench::write_bench4_json),
        "bench5" => cmd_bench_artifact(&args, scale, "BENCH_5.json", bench::write_bench5_json),
        "bench6" => cmd_bench_artifact(&args, scale, "BENCH_6.json", bench::write_bench6_json),
        "bench7" => cmd_bench_artifact(&args, scale, "BENCH_7.json", bench::write_bench7_json),
        "bench8" => cmd_bench_artifact(&args, scale, "BENCH_8.json", bench::write_bench8_json),
        "bench9" => cmd_bench_artifact(&args, scale, "BENCH_9.json", bench::write_bench9_json),
        "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => Err(format!("unknown subcommand `{other}` (try `px-amr help`)")),
    };
    if let Err(e) = result {
        eprintln!("px-amr: {e}");
        std::process::exit(1);
    }
}

/// Uniform `--backend` handling for `run`/`dist`/bench subcommands: the
/// flag wins, then `PX_BACKEND`, then `native`; unknown values are
/// rejected with the valid list. The validated choice is written back to
/// `PX_BACKEND` so the bench implementations (which read the env) follow
/// the CLI.
fn backend_arg(args: &Args) -> Result<BackendKind, String> {
    let default = std::env::var("PX_BACKEND").unwrap_or_else(|_| "native".to_string());
    let s = args.get("backend", &default);
    let kind: BackendKind = s.parse()?;
    std::env::set_var("PX_BACKEND", s);
    Ok(kind)
}

/// Shared driver for the `benchN` subcommands: validate `--backend`,
/// reject unknown options, run the experiment, report the artifact path.
fn cmd_bench_artifact(
    args: &Args,
    scale: bench::Scale,
    label: &str,
    write: fn(bench::Scale) -> std::io::Result<(std::path::PathBuf, String)>,
) -> Result<(), String> {
    let _ = backend_arg(args)?;
    let unknown = args.unknown();
    if !unknown.is_empty() {
        return Err(format!("unknown options: {}", unknown.join(", ")));
    }
    match write(scale) {
        Ok((path, table)) => {
            print!("{table}");
            println!("{label} written to {}", path.display());
            Ok(())
        }
        Err(e) => Err(format!("{label} experiment failed: {e}")),
    }
}

fn print_help() {
    println!(
        "px-amr — ParalleX execution-model reproduction (Anderson et al. 2011)\n\n\
         usage: px-amr <run|info|fig2|fig3|fig5|fig6|fig7|fig8|fig9|fpga|dist|bench3|bench4|bench5|bench6|bench7|bench8|bench9> [--options]\n\n\
         run options:  --n0 1601 --levels 2 --steps 32 --granularity 16\n\
                       --workers <cores> --backend native|fused|simd|xla\n\
                       --scheduler local|global\n\
                       --barrier --epochs 1 --amplitude 0.05 --deadline-ms 0\n\
                       --localities 1 --placement slabs|weighted|adaptive|wire\n\
                       --wire-alpha 1.0 (wire placement: weight of compute\n\
                       imbalance vs cut bytes in the packing objective)\n\
                       --trace out.json (flight recorder on; writes the run as\n\
                       Perfetto-loadable trace JSON + causal summary)\n\
         dist options: --backend native|fused|simd|xla (physics backend)\n\
                       --trace out.json (flight recorder over the experiment)\n\
                       --placement slabs|weighted|adaptive|wire (default slabs +\n\
                       balancer; wire uses its cold-start map here — the carried\n\
                       traffic feedback loop lives in `run --placement wire`)\n\
                       --elastic \"25:-3,25:-2,60:+2,60:+3\" (scripted membership\n\
                       changes at task-completion percentages: -L leave, +L join)\n\
                       --kill <L>@<frac> (kill locality L unplanned at the given\n\
                       task-completion fraction; detected + recovered, no drain)\n\
                       --loss-rate <p> (seeded irrecoverable parcel loss — the\n\
                       epoch must fail cleanly, not hang)\n\
         bench3:       batched vs per-fragment ghost exchange and static vs\n\
                       adaptive placement across 1/2/4/8 localities (BENCH_3.json)\n\
         bench4:       elastic localities — steady vs shrink-mid-run vs\n\
                       grow-mid-run across 1/2/4/8 localities (BENCH_4.json)\n\
         bench5:       crash tolerance — steady vs checkpointed vs one unplanned\n\
                       locality death mid-run across 2/4/8 localities (BENCH_5.json)\n\
         bench6:       kernel fast path — native vs fused vs simd ns/step across\n\
                       block sizes and 1/2/4/8 localities (BENCH_6.json)\n\
         bench7:       deterministic replay — dataflow (LCO) vs global barrier\n\
                       on the virtual clock over the measured DAG (BENCH_7.json)\n\
         bench8:       wire-aware placement — traffic-refined packing vs adaptive\n\
                       under regridding + elastic membership, plus strong scaling\n\
                       across 1/2/4/8 localities x slabs/adaptive/wire (BENCH_8.json)\n\
         bench9:       flight-recorder causal tracing — critical path vs total\n\
                       work over level depth x 1/2/4/8 localities x dataflow/\n\
                       barrier, with the tracing-tax headline (BENCH_9.json)\n\
                       (bench subcommands also accept --backend)\n\
         env: PX_SCALE=quick|full  PX_BACKEND=native|fused|simd|xla  PX_ARTIFACTS=<dir>"
    );
}

fn cmd_dist(args: &Args, scale: bench::Scale) -> Result<(), String> {
    let _ = backend_arg(args)?;
    let placement: PlacementPolicy = args
        .get_choice("placement", &PlacementPolicy::CLI_NAMES, "slabs")?
        .parse()?;
    let elastic = args.get("elastic", "");
    let kill = args.get("kill", "");
    let loss_rate: f64 = args.get_parse("loss-rate", 0.0)?;
    let trace_out = args.get("trace", "");
    let unknown = args.unknown();
    if !unknown.is_empty() {
        return Err(format!("unknown options: {}", unknown.join(", ")));
    }
    // Flight recorder around the whole experiment: rings outlive the
    // runtimes the experiment boots internally, so one harvest at the
    // end covers every locality it ran.
    let _session = (!trace_out.is_empty()).then(trace::exclusive_session);
    if !trace_out.is_empty() {
        trace::reset();
        trace::enable(trace::DEFAULT_CAPACITY);
    }
    let result = (|| -> Result<(), String> {
        if !kill.is_empty() || loss_rate > 0.0 {
            // Failure-injection epoch, e.g. `px-amr dist --kill 2@0.35`
            // (unplanned death of locality 2 at 35% task completion) or
            // `px-amr dist --loss-rate 0.01` (irrecoverable wire loss).
            if !elastic.is_empty() {
                return Err("--kill/--loss-rate and --elastic are separate demos".into());
            }
            let report = bench::run_crash_demo(scale, &kill, loss_rate, placement)?;
            print!("{report}");
            return Ok(());
        }
        if !elastic.is_empty() {
            // Scripted membership-change epoch, e.g.
            // `px-amr dist --elastic "25:-3,25:-2,60:+2,60:+3"`.
            let report = bench::run_elastic_demo(scale, &elastic, placement)?;
            print!("{report}");
            return Ok(());
        }
        match bench::write_bench2_json(scale, placement) {
            Ok((path, table)) => {
                print!("{table}");
                println!("BENCH_2.json written to {}", path.display());
                Ok(())
            }
            Err(e) => Err(format!("dist experiment failed: {e}")),
        }
    })();
    if !trace_out.is_empty() {
        trace::disable();
        let rings = trace::harvest();
        let stats = trace::analyze(&rings);
        print!("{}", stats.render());
        trace::write_perfetto(&trace_out, &rings)
            .map_err(|e| format!("--trace {trace_out}: {e}"))?;
        println!("trace written to {trace_out} (open in ui.perfetto.dev or chrome://tracing)");
        trace::reset();
    }
    result
}

fn cmd_info() -> Result<(), String> {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0);
    println!("px-amr info");
    println!("  cores                : {cores}");
    println!("  scale (PX_SCALE)     : {:?}", bench::Scale::from_env());
    let dir = std::env::var("PX_ARTIFACTS")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string());
    match parallex::runtime::XlaCompute::open(&dir) {
        Ok(xc) => {
            println!("  artifacts            : {dir}");
            for e in xc.manifest() {
                println!(
                    "    step_b{:<4} in={} out={} vmem~{}B sha={}",
                    e.block, e.input_len, e.output_len, e.vmem_bytes, e.hlo_sha256
                );
            }
        }
        Err(e) => println!("  artifacts            : unavailable ({e})"),
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<(), String> {
    let n0: usize = args.get_parse("n0", 1601)?;
    let levels: usize = args.get_parse("levels", 2)?;
    let steps: u64 = args.get_parse("steps", 32)?;
    let granularity: usize = args.get_parse("granularity", 16)?;
    let workers: usize = args.get_parse(
        "workers",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    )?;
    let kind = backend_arg(args)?;
    let scheduler: SchedPolicyKind = args.get("scheduler", "local").parse()?;
    let barrier = args.flag("barrier");
    let epochs: u64 = args.get_parse("epochs", 1)?;
    let amplitude: f64 = args.get_parse("amplitude", 0.05)?;
    let deadline_ms: u64 = args.get_parse("deadline-ms", 0)?;
    let localities: usize = args.get_parse("localities", 1)?;
    let placement: PlacementPolicy = args
        .get_choice("placement", &PlacementPolicy::CLI_NAMES, "weighted")?
        .parse()?;
    let wire_alpha: f64 = args.get_parse("wire-alpha", 1.0)?;
    let trace_out = args.get("trace", "");
    let unknown = args.unknown();
    if !unknown.is_empty() {
        return Err(format!("unknown options: {}", unknown.join(", ")));
    }

    let dir = std::env::var("PX_ARTIFACTS")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string());
    let backend = make_backend(kind, &dir).map_err(|e| e.to_string())?;

    let mesh = MeshConfig { r_max: 20.0, n0, levels, cfl: 0.25, granularity };
    let regrid_cfg = RegridConfig { error_threshold: 2e-4, buffer: 16 };
    let mut hierarchy_current =
        initial_hierarchy(mesh, regrid_cfg, amplitude, 8.0, 1.0).map_err(|e| e.to_string())?;

    println!(
        "px-amr run: n0={n0} levels={} (built {}) steps={steps} g={granularity} workers={workers} \
         backend={} scheduler={scheduler:?} barrier={barrier} epochs={epochs} placement={}",
        levels,
        hierarchy_current.n_levels() - 1,
        backend.name(),
        placement.name()
    );

    // Enable the flight recorder before boot so worker rings capture the
    // run from the first task.
    let _session = (!trace_out.is_empty()).then(trace::exclusive_session);
    if !trace_out.is_empty() {
        trace::reset();
        trace::enable(trace::DEFAULT_CAPACITY);
    }
    let rt = PxRuntime::boot(PxConfig {
        localities,
        workers_per_locality: workers,
        policy: scheduler,
        net: if localities > 1 { NetModel::cluster_like() } else { NetModel::instant() },
    });

    let cfg = AmrConfig {
        amplitude,
        r0: 8.0,
        delta: 1.0,
        coarse_steps: steps,
        barrier,
        deadline: (deadline_ms > 0).then(|| std::time::Duration::from_millis(deadline_ms)),
    };

    let opts = DistAmrOpts { policy: placement, ..Default::default() };
    // The adaptive feedback loop: one cost model carried across every
    // epoch/regrid boundary of this run. Wire placement additionally
    // carries the observed parcel-traffic model (DESIGN.md §12).
    let mut model = CostModel::new();
    let mut traffic = TrafficModel::new();
    let mut init = None;
    let t0 = std::time::Instant::now();
    for epoch in 0..epochs {
        let plan = Arc::new(EpochPlan::new(hierarchy_current.clone(), cfg.coarse_steps));
        let init_states = match init.take() {
            Some(s) => s,
            None => initial_block_states(&plan, &cfg),
        };
        let outcome = if placement == PlacementPolicy::Wire {
            run_epoch_wire(
                &rt,
                plan.clone(),
                backend.clone(),
                cfg,
                &init_states,
                &opts,
                &mut model,
                &mut traffic,
                wire_alpha,
            )
        } else if placement == PlacementPolicy::Adaptive {
            run_epoch_adaptive(&rt, plan.clone(), backend.clone(), cfg, &init_states, &opts, &mut model)
        } else {
            run_epoch_placed(&rt, plan.clone(), backend.clone(), cfg, &init_states, &opts)
        }
        .map_err(|e| e.to_string())?;
        // Per-epoch report.
        let counters = rt.counters_total();
        let (reg0, f0) = outcome.region_state(&plan, 0, 0);
        let dx0 = plan.hierarchy.config.dx(0);
        let r0s: Vec<f64> = (reg0.lo..reg0.hi).map(|i| dx0 * i as f64).collect();
        println!(
            "epoch {epoch}: tasks={} frozen={} elapsed={} threads={} steals={} max|u|={:.3e} E={:.6e}",
            outcome.tasks_run,
            outcome.tasks_frozen,
            fmt_dur(outcome.elapsed),
            counters.threads_spawned,
            counters.steals,
            f0.max_abs(),
            energy_norm(&f0, &r0s, dx0),
        );
        for l in 0..plan.hierarchy.n_levels() {
            println!(
                "  level {l}: regions={} min_steps={}",
                plan.hierarchy.regions[l].len(),
                outcome.min_steps(&plan, l)
            );
        }
        if epoch + 1 < epochs {
            let comp = Composite::new(&plan, &outcome);
            let new_h = regrid_hierarchy(&comp, regrid_cfg).map_err(|e| e.to_string())?;
            let new_plan = EpochPlan::new(new_h.clone(), cfg.coarse_steps);
            init = Some(remap(&comp, &new_plan));
            hierarchy_current = new_h;
            println!("  regrid: levels now {}", hierarchy_current.n_levels() - 1);
        }
    }
    println!("total wallclock {}", fmt_dur(t0.elapsed()));
    println!("counters:\n{}", rt.counters_total().render());
    if !trace_out.is_empty() {
        rt.wait_quiescent();
        trace::disable();
        let rings = trace::harvest();
        let stats = trace::analyze(&rings);
        print!("{}", stats.render());
        trace::write_perfetto(&trace_out, &rings)
            .map_err(|e| format!("--trace {trace_out}: {e}"))?;
        println!("trace written to {trace_out} (open in ui.perfetto.dev or chrome://tracing)");
        trace::reset();
    }
    rt.shutdown();
    Ok(())
}
