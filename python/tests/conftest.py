"""Shared pytest config: force x64 before any jax import in tests."""

import jax

jax.config.update("jax_enable_x64", True)
