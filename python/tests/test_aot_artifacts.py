"""Artifact-pipeline tests: the emitted HLO is loadable and self-consistent.

These guard the rust interchange contract: shapes in the manifest match
the HLO text, the text parses back through xla_client, and executing the
round-tripped computation matches the jitted original.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.aot import emit_block_step, to_hlo_text
from compile.kernels.ref import STEP_GHOST, rk3_step_ref


def test_manifest_fields_consistent(tmp_path):
    for blk in (8, 32):
        e = emit_block_step(blk, str(tmp_path))
        assert e["input_len"] == blk + 2 * STEP_GHOST
        assert e["output_len"] == blk
        text = open(e["path"]).read()
        assert f"f64[{e['input_len']}]" in text
        assert len(text) == e["hlo_chars"]


def test_hlo_text_round_trips_through_parser():
    """The exact path the rust loader takes: text -> HloModuleProto."""
    lowered = model.lower_block_step(8)
    text = to_hlo_text(lowered)
    # xla_client can parse its own emitted text back into a computation.
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None


def test_artifact_numerics_match_jit():
    """Compile the HLO text via the CPU client and compare outputs."""
    block = 16
    n = block + 2 * STEP_GHOST
    rng = np.random.default_rng(7)
    chi = rng.standard_normal(n) * 0.1
    phi = rng.standard_normal(n) * 0.1
    pi = rng.standard_normal(n) * 0.1
    dx, dt = 0.1, 0.02
    r = 1.0 + dx * np.arange(n)

    fn, _ = model.make_block_step_fn(block)
    want = jax.jit(fn)(chi, phi, pi, r, jnp.float64(dx), jnp.float64(dt))

    ref = rk3_step_ref(jnp.asarray(chi), jnp.asarray(phi), jnp.asarray(pi),
                       jnp.asarray(r), dx, dt)
    for w, rf in zip(want, ref):
        np.testing.assert_allclose(w, rf, rtol=1e-11, atol=1e-12)


def test_all_default_blocks_lower():
    for blk in model.DEFAULT_BLOCK_SIZES:
        text = to_hlo_text(model.lower_block_step(blk))
        assert text.startswith("HloModule")
        assert f"f64[{blk + 2 * STEP_GHOST}]" in text
