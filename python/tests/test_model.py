"""L2 correctness: block-step model, physics invariants, AOT lowering."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref
from compile.kernels.ref import STEP_GHOST


def make_grid(n, dx, r_start=0.0):
    return jnp.asarray(r_start + dx * np.arange(n), jnp.float64)


class TestBlockStep:
    def test_block_step_matches_ref(self):
        rng = np.random.default_rng(0)
        block, dx = 32, 0.1
        n = block + 2 * STEP_GHOST
        dt = 0.25 * dx
        r = make_grid(n, dx, 2.0)
        chi = jnp.asarray(rng.standard_normal(n) * 0.3)
        phi = jnp.asarray(rng.standard_normal(n) * 0.3)
        pi = jnp.asarray(rng.standard_normal(n) * 0.3)
        got = model.block_step(chi, phi, pi, r, dx, dt)
        want = ref.rk3_step_ref(chi, phi, pi, r, dx, dt)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-11, atol=1e-12)

    def test_composed_equals_fused(self):
        """Ablation pair: 3x RHS calls vs single fused kernel agree."""
        rng = np.random.default_rng(1)
        block, dx = 16, 0.05
        n = block + 2 * STEP_GHOST
        dt = 0.2 * dx
        r = make_grid(n, dx, 1.0)
        args = [jnp.asarray(rng.standard_normal(n) * 0.2) for _ in range(3)]
        fused = model.block_step(*args, r, dx, dt)
        composed = model.block_step_composed(*args, r, dx, dt)
        for f, c in zip(fused, composed):
            np.testing.assert_allclose(f, c, rtol=1e-12, atol=1e-13)

    def test_jit_block_step_fn(self):
        """The exact function lowered by aot.py runs under jit."""
        fn, specs = model.make_block_step_fn(8)
        jitted = jax.jit(fn)
        rng = np.random.default_rng(2)
        n = specs[0].shape[0]
        args = [jnp.asarray(rng.standard_normal(n) * 0.1) for _ in range(3)]
        r = make_grid(n, 0.1, 4.0)
        out = jitted(*args, r, jnp.float64(0.1), jnp.float64(0.02))
        assert all(o.shape == (8,) for o in out)
        want = ref.rk3_step_ref(*args, r, 0.1, 0.02)
        for g, w in zip(out, want):
            np.testing.assert_allclose(g, w, rtol=1e-11, atol=1e-12)


class TestPhysics:
    def test_linear_wave_packet_advects_outward(self):
        """Small-amplitude pulse: energy moves outward at speed ~1.

        Evolves a tiny pulse on a single grid (no AMR) via repeated block
        steps and checks the radius of max |chi| grows at ~unit speed.
        """
        dx = 0.05
        n = 800
        r = make_grid(n, dx, 0.0)
        chi, phi, pi = ref.initial_data_ref(r, amplitude=1e-6, r0=8.0, delta=1.0)
        dt = 0.25 * dx
        steps = 200

        state = (chi, phi, pi)
        # Evolve the interior; pad with frozen boundary values each step
        # (pulse stays far from both boundaries for this test).
        for _ in range(steps):
            out = ref.rk3_step_ref(*state, r, dx, dt)
            state = tuple(
                jnp.concatenate([f[: STEP_GHOST], o, f[-STEP_GHOST:]])
                for f, o in zip(state, out)
            )
        # The pulse splits into in/outgoing halves; the *outgoing front*
        # (outermost radius with non-negligible energy) advances at the
        # characteristic speed 1.
        def front(phi_arr):
            w = np.asarray(phi_arr) ** 2
            thresh = 1e-6 * w.max()
            return float(np.asarray(r)[np.nonzero(w > thresh)[0].max()])

        _, phi0_ref, _ = ref.initial_data_ref(r, amplitude=1e-6, r0=8.0, delta=1.0)
        f0 = front(phi0_ref)
        f1 = front(state[1])
        t_elapsed = steps * dt
        advance = f1 - f0
        assert 0.7 * t_elapsed < advance < 1.3 * t_elapsed, (
            f"front moved {advance}, expected ~{t_elapsed}"
        )

    def test_convergence_second_order(self):
        """FD operator converges at 2nd order on a smooth profile."""
        errs = []
        for n in (100, 200, 400):
            dx = 10.0 / n
            r = make_grid(n, dx, 1.0)  # away from origin
            chi = jnp.sin(r)
            phi = jnp.cos(r)  # = d_r chi exactly
            pi = jnp.zeros_like(r)
            _, phi_t, pi_t = ref.rhs_ref(chi, phi, pi, r, dx)
            # Continuum: pi_t = (1/r^2) d_r(r^2 cos r) + sin^7 r
            r_c = r[1:-1]
            exact = -jnp.sin(r_c) + 2 * jnp.cos(r_c) / r_c + jnp.sin(r_c) ** 7
            errs.append(float(jnp.max(jnp.abs(pi_t - exact))))
        order01 = np.log2(errs[0] / errs[1])
        order12 = np.log2(errs[1] / errs[2])
        assert 1.8 < order01 < 2.2, f"orders {order01}, {order12}; errs {errs}"
        assert 1.8 < order12 < 2.2

    def test_initial_data_matches_paper_params(self):
        r = make_grid(400, 0.05, 0.0)
        chi, phi, pi = ref.initial_data_ref(r, amplitude=0.01)
        i_max = int(jnp.argmax(chi))
        assert abs(float(r[i_max]) - 8.0) < 0.06  # peaked at R0 = 8
        assert float(jnp.max(jnp.abs(pi))) == 0.0
        # Phi is the exact derivative of the gaussian.
        np.testing.assert_allclose(
            np.asarray(phi),
            np.asarray(chi * (-2.0 * (r - 8.0) / 1.0)),
            rtol=1e-12,
        )


class TestAotLowering:
    def test_lowered_hlo_text_is_parseable_header(self):
        from compile.aot import to_hlo_text

        lowered = model.lower_block_step(8)
        text = to_hlo_text(lowered)
        assert text.startswith("HloModule"), text[:80]
        # 6 parameters (chi, phi, pi, r, dx, dt), tuple return.
        assert "f64[14]" in text  # 8 + 6 ghosts
        assert "(f64[8]" in text or "f64[8]" in text

    def test_lowering_is_deterministic(self):
        from compile.aot import to_hlo_text

        a = to_hlo_text(model.lower_block_step(16))
        b = to_hlo_text(model.lower_block_step(16))
        assert a == b

    def test_emit_block_step_writes_artifact(self, tmp_path):
        from compile.aot import emit_block_step

        e = emit_block_step(8, str(tmp_path))
        assert os.path.exists(e["path"])
        assert e["input_len"] == 14 and e["output_len"] == 8
        text = open(e["path"]).read()
        assert text.startswith("HloModule")
