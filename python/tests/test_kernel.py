"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

This is the core correctness signal for the compute layer: the stencil
kernel and the fused RK3 kernel must match ``ref.py`` to tight tolerance
across hypothesis-swept shapes, amplitudes and grid placements (including
blocks touching the r=0 regularized origin).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, stencil

RTOL = 1e-12
ATOL = 1e-13


def make_grid(n, dx, r_start):
    return jnp.asarray(r_start + dx * np.arange(n), jnp.float64)


def random_state(rng, n, amp=1.0):
    chi = jnp.asarray(amp * rng.standard_normal(n))
    phi = jnp.asarray(amp * rng.standard_normal(n))
    pi = jnp.asarray(amp * rng.standard_normal(n))
    return chi, phi, pi


class TestRhsKernel:
    def test_matches_ref_simple(self):
        rng = np.random.default_rng(0)
        n, dx = 32, 0.1
        r = make_grid(n, dx, 1.0)
        chi, phi, pi = random_state(rng, n)
        got = stencil.rhs_pallas(chi, phi, pi, r, dx)
        want = ref.rhs_ref(chi, phi, pi, r, dx)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=RTOL, atol=ATOL)

    def test_matches_ref_at_origin(self):
        """Block containing r=0 uses the l'Hopital-regularized term."""
        rng = np.random.default_rng(1)
        n, dx = 16, 0.125
        r = make_grid(n, dx, 0.0)  # r[0] == 0 exactly
        chi, phi, pi = random_state(rng, n)
        got = stencil.rhs_pallas(chi, phi, pi, r, dx)
        want = ref.rhs_ref(chi, phi, pi, r, dx)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=RTOL, atol=ATOL)
        assert bool(jnp.all(jnp.isfinite(got[2])))

    def test_chi7_power_identity(self):
        """The squaring factorization equals jnp power to round-off."""
        x = jnp.linspace(-2.0, 2.0, 101, dtype=jnp.float64)
        x2 = x * x
        x4 = x2 * x2
        np.testing.assert_allclose(x * x2 * x4, x**7, rtol=1e-14)

    def test_minimum_block(self):
        n, dx = 3, 0.1
        r = make_grid(n, dx, 2.0)
        chi = jnp.ones(n, jnp.float64)
        phi = jnp.zeros(n, jnp.float64)
        pi = jnp.zeros(n, jnp.float64)
        (chi_t, phi_t, pi_t) = stencil.rhs_pallas(chi, phi, pi, r, dx)
        assert chi_t.shape == (1,)
        # chi=1, phi=pi=0: chi_t = 0, phi_t = 0, pi_t = 1^7 = 1.
        np.testing.assert_allclose(pi_t, [1.0], rtol=1e-14)

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(min_value=3, max_value=257),
        seed=st.integers(min_value=0, max_value=2**31),
        dx_exp=st.integers(min_value=-6, max_value=0),
        r_start=st.floats(min_value=0.0, max_value=50.0),
        amp=st.floats(min_value=1e-3, max_value=2.0),
    )
    def test_hypothesis_matches_ref(self, n, seed, dx_exp, r_start, amp):
        rng = np.random.default_rng(seed)
        dx = 2.0**dx_exp
        r = make_grid(n, dx, r_start)
        chi, phi, pi = random_state(rng, n, amp)
        got = stencil.rhs_pallas(chi, phi, pi, r, dx)
        want = ref.rhs_ref(chi, phi, pi, r, dx)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-11, atol=1e-12)


class TestFusedRk3Kernel:
    def test_matches_ref_step(self):
        rng = np.random.default_rng(2)
        n, dx = 38, 0.05
        dt = 0.4 * dx
        r = make_grid(n, dx, 3.0)
        chi, phi, pi = random_state(rng, n, 0.5)
        got = stencil.rk3_step_fused_pallas(chi, phi, pi, r, dx, dt)
        want = ref.rk3_step_ref(chi, phi, pi, r, dx, dt)
        for g, w in zip(got, want):
            assert g.shape == (n - 6,)
            np.testing.assert_allclose(g, w, rtol=1e-11, atol=1e-12)

    def test_matches_ref_step_at_origin(self):
        rng = np.random.default_rng(3)
        n, dx = 22, 0.25
        dt = 0.1 * dx
        r = make_grid(n, dx, 0.0)
        chi, phi, pi = random_state(rng, n, 0.3)
        got = stencil.rk3_step_fused_pallas(chi, phi, pi, r, dx, dt)
        want = ref.rk3_step_ref(chi, phi, pi, r, dx, dt)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-11, atol=1e-12)

    @settings(max_examples=20, deadline=None)
    @given(
        block=st.integers(min_value=1, max_value=128),
        seed=st.integers(min_value=0, max_value=2**31),
        cfl=st.floats(min_value=0.05, max_value=0.5),
        r_start=st.floats(min_value=0.0, max_value=20.0),
    )
    def test_hypothesis_fused_matches_ref(self, block, seed, cfl, r_start):
        rng = np.random.default_rng(seed)
        n = block + 6
        dx = 0.1
        dt = cfl * dx
        r = make_grid(n, dx, r_start)
        chi, phi, pi = random_state(rng, n, 0.4)
        got = stencil.rk3_step_fused_pallas(chi, phi, pi, r, dx, dt)
        want = ref.rk3_step_ref(chi, phi, pi, r, dx, dt)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-11, atol=1e-12)

    def test_dt_zero_is_identity_on_interior(self):
        rng = np.random.default_rng(4)
        n, dx = 20, 0.1
        r = make_grid(n, dx, 5.0)
        chi, phi, pi = random_state(rng, n)
        got = stencil.rk3_step_fused_pallas(chi, phi, pi, r, dx, 0.0)
        np.testing.assert_allclose(got[0], chi[3:-3], rtol=1e-14)
        np.testing.assert_allclose(got[1], phi[3:-3], rtol=1e-14)
        np.testing.assert_allclose(got[2], pi[3:-3], rtol=1e-14)


class TestVmemFootprint:
    def test_footprint_scales_linearly(self):
        a = stencil.vmem_footprint_bytes(64)
        b = stencil.vmem_footprint_bytes(128)
        assert a < b < 2.2 * a

    def test_all_default_blocks_fit_vmem(self):
        """Every artifact block size stays far below ~16 MiB TPU VMEM."""
        from compile.model import DEFAULT_BLOCK_SIZES

        for blk in DEFAULT_BLOCK_SIZES:
            assert stencil.vmem_footprint_bytes(blk) < 16 * 2**20 / 4
