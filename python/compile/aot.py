"""AOT bridge: lower the Layer-2 block-step to HLO text artifacts.

Run once by ``make artifacts``; the rust coordinator loads the emitted
``artifacts/step_b{N}.hlo.txt`` files via the PJRT C API (`xla` crate) and
executes them on its request path. Python is never invoked at runtime.

HLO **text** (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts [--blocks 8,16,...]

A manifest (``manifest.txt``) records block sizes, shapes, dtype and the
VMEM footprint estimate per artifact so the rust side can sanity-check
what it loads, and EXPERIMENTS.md §Perf can cite the numbers.
"""

from __future__ import annotations

import argparse
import hashlib
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .kernels import stencil  # noqa: E402
from .kernels.ref import STEP_GHOST  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit_block_step(block: int, out_dir: str) -> dict:
    """Lower one block size; returns its manifest entry."""
    lowered = model.lower_block_step(block)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"step_b{block}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    n = block + 2 * STEP_GHOST
    return {
        "block": block,
        "path": path,
        "input_len": n,
        "output_len": block,
        "dtype": "f64",
        "vmem_bytes": stencil.vmem_footprint_bytes(block),
        "hlo_sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        "hlo_chars": len(text),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--blocks",
        default=",".join(str(b) for b in model.DEFAULT_BLOCK_SIZES),
        help="comma-separated block sizes to lower",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    blocks = [int(b) for b in args.blocks.split(",") if b]
    entries = []
    for b in blocks:
        e = emit_block_step(b, args.out_dir)
        entries.append(e)
        print(
            f"wrote {e['path']}  in={e['input_len']} out={e['output_len']} "
            f"vmem~{e['vmem_bytes']}B sha={e['hlo_sha256']}"
        )
    manifest = os.path.join(args.out_dir, "manifest.txt")
    with open(manifest, "w") as f:
        f.write("# block input_len output_len dtype vmem_bytes hlo_sha256\n")
        for e in entries:
            f.write(
                f"{e['block']} {e['input_len']} {e['output_len']} "
                f"{e['dtype']} {e['vmem_bytes']} {e['hlo_sha256']}\n"
            )
    print(f"wrote {manifest} ({len(entries)} artifacts)")


if __name__ == "__main__":
    main()
