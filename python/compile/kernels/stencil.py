"""Layer-1 Pallas kernels: the finite-difference hot spot.

The paper's compute hot path is the per-block RHS evaluation of the
semilinear wave system (Eqns. 1-3) inside every RK3 stage. Here it is a
Pallas kernel so the HBM<->VMEM staging of one *task-granularity block*
(the paper's Fig 4 parameter) is explicit: one `pallas_call` program
instance owns one block (plus stencil ghosts) in VMEM and writes the
block's RHS.

TPU adaptation note (DESIGN.md §Hardware-Adaptation): this is a 1-D
3-point stencil — pure VPU work, no MXU. The natural TPU mapping keeps a
whole task block (hundreds of f64 points, ~KBs) resident in VMEM across
all three RK stages; `rk3_stage_fused_pallas` below does exactly that, so
HBM traffic per step is one block read + one write instead of three.

Kernels are lowered with ``interpret=True``: the CPU PJRT client used by
the rust coordinator cannot execute Mosaic custom-calls, and interpret
mode lowers to plain HLO while preserving the kernel's block structure
(see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import P_EXPONENT, R_ORIGIN_EPS

# All kernels run in interpret mode (CPU PJRT target); see module docstring.
INTERPRET = True


def _rhs_body(chi, phi, pi, r, inv_2dx):
    """Elementwise RHS given pre-sliced neighbor views (length n-2 each).

    Arguments are tuples ``(left, center, right)`` views for the stencil
    fields and the center view of ``r``; shared by both kernels.
    """
    phi_l, phi_c, phi_r = phi
    pi_l, pi_c, pi_r = pi
    chi_c = chi
    dr_pi = (pi_r - pi_l) * inv_2dx
    dr_phi = (phi_r - phi_l) * inv_2dx
    at_origin = jnp.abs(r) < R_ORIGIN_EPS
    safe_r = jnp.where(at_origin, 1.0, r)
    spherical = jnp.where(at_origin, 3.0 * dr_phi, dr_phi + 2.0 * phi_c / safe_r)
    chi_t = pi_c
    phi_t = dr_pi
    # chi^7 via squarings: chi^7 = chi * (chi^2) * (chi^4) — 3 multiplies
    # on the VPU instead of a transcendental pow.
    chi2 = chi_c * chi_c
    chi4 = chi2 * chi2
    pi_t = spherical + chi_c * chi2 * chi4
    return chi_t, phi_t, pi_t


def _rhs_kernel(chi_ref, phi_ref, pi_ref, r_ref, out_chi, out_phi, out_pi, *, inv_2dx):
    """Pallas kernel: RHS on the interior of one VMEM-resident block."""
    chi = chi_ref[...]
    phi = phi_ref[...]
    pi = pi_ref[...]
    r = r_ref[...]
    chi_t, phi_t, pi_t = _rhs_body(
        chi[1:-1],
        (phi[:-2], phi[1:-1], phi[2:]),
        (pi[:-2], pi[1:-1], pi[2:]),
        r[1:-1],
        inv_2dx,
    )
    out_chi[...] = chi_t
    out_phi[...] = phi_t
    out_pi[...] = pi_t


def rhs_pallas(chi, phi, pi, r, dx):
    """RHS of Eqns. (1)-(3) as a Pallas call; output length = n - 2.

    Matches ``ref.rhs_ref`` to floating-point round-off (same operation
    order up to the chi^7 factorization).
    """
    n = chi.shape[0]
    assert n >= 3, "need at least one interior point"
    out_shape = tuple(
        jax.ShapeDtypeStruct((n - 2,), chi.dtype) for _ in range(3)
    )
    kernel = functools.partial(_rhs_kernel, inv_2dx=1.0 / (2.0 * dx))
    return pl.pallas_call(kernel, out_shape=out_shape, interpret=INTERPRET)(
        chi, phi, pi, r
    )


def _rk3_fused_kernel(chi_ref, phi_ref, pi_ref, r_ref, scal_ref, out_chi, out_phi, out_pi):
    """Fused SSP-RK3 step for one block: all three stages in VMEM.

    Input refs have length ``n`` (block + 3 ghosts/side); outputs have
    length ``n - 6``. No HBM round-trip between stages — the TPU-shaped
    optimization the three-call composition cannot express. ``scal_ref``
    carries ``[1/(2 dx), dt]`` as runtime scalars so a single compiled
    artifact serves every refinement level (each level halves dx and dt).
    """
    inv_2dx = scal_ref[0]
    dt = scal_ref[1]

    def rhs(chi, phi, pi, r):
        return _rhs_body(
            chi[1:-1],
            (phi[:-2], phi[1:-1], phi[2:]),
            (pi[:-2], pi[1:-1], pi[2:]),
            r[1:-1],
            inv_2dx,
        )

    chi0 = chi_ref[...]
    phi0 = phi_ref[...]
    pi0 = pi_ref[...]
    r0 = r_ref[...]

    # Stage 1 (valid 1..n-1)
    k1c, k1p, k1q = rhs(chi0, phi0, pi0, r0)
    chi1 = chi0[1:-1] + dt * k1c
    phi1 = phi0[1:-1] + dt * k1p
    pi1 = pi0[1:-1] + dt * k1q
    r1 = r0[1:-1]

    # Stage 2 (valid 2..n-2)
    k2c, k2p, k2q = rhs(chi1, phi1, pi1, r1)
    chi2 = 0.75 * chi0[2:-2] + 0.25 * (chi1[1:-1] + dt * k2c)
    phi2 = 0.75 * phi0[2:-2] + 0.25 * (phi1[1:-1] + dt * k2p)
    pi2 = 0.75 * pi0[2:-2] + 0.25 * (pi1[1:-1] + dt * k2q)
    r2 = r1[1:-1]

    # Stage 3 (valid 3..n-3)
    k3c, k3p, k3q = rhs(chi2, phi2, pi2, r2)
    third = 1.0 / 3.0
    two_third = 2.0 / 3.0
    out_chi[...] = third * chi0[3:-3] + two_third * (chi2[1:-1] + dt * k3c)
    out_phi[...] = third * phi0[3:-3] + two_third * (phi2[1:-1] + dt * k3p)
    out_pi[...] = third * pi0[3:-3] + two_third * (pi2[1:-1] + dt * k3q)


def rk3_step_fused_pallas(chi, phi, pi, r, dx, dt):
    """One full RK3 step as a single fused Pallas kernel.

    Input length ``n`` (block + 6 ghosts); output length ``n - 6``.
    ``dx``/``dt`` may be python floats or traced rank-0 values; they enter
    the kernel as a 2-element VMEM scalar vector, so the lowered artifact
    keeps them as runtime parameters.
    """
    n = chi.shape[0]
    assert n >= 7, "need block + 3 ghosts per side"
    out_shape = tuple(
        jax.ShapeDtypeStruct((n - 6,), chi.dtype) for _ in range(3)
    )
    scal = jnp.stack(
        [1.0 / (2.0 * jnp.asarray(dx, chi.dtype)), jnp.asarray(dt, chi.dtype)]
    )
    return pl.pallas_call(_rk3_fused_kernel, out_shape=out_shape, interpret=INTERPRET)(
        chi, phi, pi, r, scal
    )


def vmem_footprint_bytes(block: int, dtype_bytes: int = 8) -> int:
    """Estimated VMEM bytes for the fused kernel at a given block size.

    4 input arrays of (block+6) + ~9 stage temporaries of <= block+4 + 3
    outputs of block. Used by DESIGN.md §Perf to check block sizes stay
    far under the ~16 MiB/core VMEM budget of a real TPU.
    """
    n = block + 6
    inputs = 4 * n
    temps = 9 * (n - 2)
    outs = 3 * block
    return (inputs + temps + outs) * dtype_bytes
