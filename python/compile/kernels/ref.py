"""Pure-jnp reference oracle for the semilinear-wave kernels.

This module is the correctness ground truth: the Pallas kernels in
``stencil.py`` and the composed RK3 step in ``model.py`` are tested
against these functions (pytest + hypothesis). Everything here is plain
``jax.numpy`` — no pallas, no custom calls — so it runs identically on any
backend and is trivially auditable against the paper's Eqns. (1)-(3):

    chi_t = Pi
    Phi_t = d_r Pi
    Pi_t  = (1/r^2) d_r (r^2 Phi) + chi^p          (p = 7)

Discretization follows the paper: 2nd-order centered finite differences in
space, third-order Shu-Osher SSP Runge-Kutta in time. The spherical term
is expanded as d_r Phi + 2 Phi / r with the regular-center limit
(l'Hopital) 3 d_r Phi at r = 0.
"""

from __future__ import annotations

import jax.numpy as jnp

# Exponent of the semilinear source term (paper §III, p = 7).
P_EXPONENT = 7

# Ghost cells consumed per RHS evaluation (centered 3-point stencil).
RHS_GHOST = 1
# Ghost cells consumed by a full RK3 step (3 RHS evaluations).
STEP_GHOST = 3

# Treat |r| below this as the coordinate origin for the regularized term.
R_ORIGIN_EPS = 1e-12


def rhs_ref(chi, phi, pi, r, dx):
    """RHS of Eqns. (1)-(3) on the interior of a block.

    Inputs have length ``n``; outputs have length ``n - 2`` (one ghost
    consumed per side). ``r`` is the radial coordinate of each point.
    """
    dr_pi = (pi[2:] - pi[:-2]) / (2.0 * dx)
    dr_phi = (phi[2:] - phi[:-2]) / (2.0 * dx)
    r_c = r[1:-1]
    phi_c = phi[1:-1]
    chi_c = chi[1:-1]
    pi_c = pi[1:-1]
    # (1/r^2) d_r(r^2 Phi) = d_r Phi + 2 Phi / r, -> 3 d_r Phi at r = 0.
    at_origin = jnp.abs(r_c) < R_ORIGIN_EPS
    safe_r = jnp.where(at_origin, 1.0, r_c)
    spherical = jnp.where(at_origin, 3.0 * dr_phi, dr_phi + 2.0 * phi_c / safe_r)
    chi_t = pi_c
    phi_t = dr_pi
    pi_t = spherical + chi_c**P_EXPONENT
    return chi_t, phi_t, pi_t


def rk3_step_ref(chi, phi, pi, r, dx, dt):
    """One SSP-RK3 step; input length ``n``, output length ``n - 6``.

    Shu-Osher form:
        u1 = u + dt L(u)
        u2 = 3/4 u + 1/4 (u1 + dt L(u1))
        u  = 1/3 u + 2/3 (u2 + dt L(u2))
    Each stage consumes one ghost cell per side.
    """
    u = (chi, phi, pi)

    # Stage 1: valid on [1, n-1).
    k1 = rhs_ref(*u, r, dx)
    u1 = tuple(f[1:-1] + dt * k for f, k in zip(u, k1))
    r1 = r[1:-1]

    # Stage 2: valid on [2, n-2).
    k2 = rhs_ref(*u1, r1, dx)
    u2 = tuple(
        0.75 * f[2:-2] + 0.25 * (f1[1:-1] + dt * k)
        for f, f1, k in zip(u, u1, k2)
    )
    r2 = r1[1:-1]

    # Stage 3: valid on [3, n-3).
    k3 = rhs_ref(*u2, r2, dx)
    out = tuple(
        f[3:-3] / 3.0 + (2.0 / 3.0) * (f2[1:-1] + dt * k)
        for f, f2, k in zip(u, u2, k3)
    )
    return out


def initial_data_ref(r, amplitude, r0=8.0, delta=1.0):
    """Paper §III initial data: gaussian pulse in chi, Phi = d_r chi, Pi = 0."""
    chi = amplitude * jnp.exp(-((r - r0) ** 2) / delta**2)
    phi = chi * (-2.0 * (r - r0) / delta**2)
    pi = jnp.zeros_like(r)
    return chi, phi, pi
