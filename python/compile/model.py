"""Layer-2 JAX model: the block-advance computation the coordinator calls.

The unit of work in the ParalleX AMR driver is "advance one
task-granularity block by one RK3 step" (paper §III/Fig 4). This module
defines that computation as a jittable JAX function composed from the
Layer-1 Pallas kernels, and is what ``aot.py`` lowers to HLO text for the
rust coordinator.

Python never runs at request time: everything here executes only during
``make artifacts`` (and in pytest).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import stencil
from .kernels.ref import STEP_GHOST

# Every artifact uses float64: the AMR error estimator differences two
# resolutions of the same solution, which f32 round-off pollutes.
DTYPE = jnp.float64

# Block sizes lowered by default: powers of two spanning the paper's
# granularity sweep (Fig 3 explores granularities from single points to
# large blocks; per-point tasks use the native rust path, XLA blocks
# start at 8).
DEFAULT_BLOCK_SIZES = (8, 16, 32, 64, 128, 256, 512)


def block_step(chi, phi, pi, r, dx, dt):
    """Advance one block by one fused RK3 step.

    Shapes: inputs ``(block + 6,)``, outputs ``(block,)`` — callers supply
    3 ghost points per side (one per RK stage; see ref.STEP_GHOST).
    Returns a tuple ``(chi', phi', pi')``.
    """
    return stencil.rk3_step_fused_pallas(chi, phi, pi, r, dx, dt)


def block_step_composed(chi, phi, pi, r, dx, dt):
    """Same step as three separate RHS pallas calls (ablation target).

    Used by tests and by the L2 perf ablation in EXPERIMENTS.md §Perf to
    quantify what stage fusion buys (HBM traffic / executable count).
    """
    u = (chi, phi, pi)
    k1 = stencil.rhs_pallas(*u, r, dx)
    u1 = tuple(f[1:-1] + dt * k for f, k in zip(u, k1))
    r1 = r[1:-1]
    k2 = stencil.rhs_pallas(*u1, r1, dx)
    u2 = tuple(
        0.75 * f[2:-2] + 0.25 * (f1[1:-1] + dt * k)
        for f, f1, k in zip(u, u1, k2)
    )
    r2 = r1[1:-1]
    k3 = stencil.rhs_pallas(*u2, r2, dx)
    return tuple(
        f[3:-3] / 3.0 + (2.0 / 3.0) * (f2[1:-1] + dt * k)
        for f, f2, k in zip(u, u2, k3)
    )


def make_block_step_fn(block: int):
    """A jittable ``f(chi, phi, pi, r, dx, dt) -> (chi', phi', pi')`` for a
    fixed block size, with dx/dt as *runtime scalars*.

    dx and dt arrive as rank-0 f64 parameters so one artifact serves every
    refinement level (each level halves both): the artifact set is keyed
    by block size only.
    """

    def fn(chi, phi, pi, r, dx, dt):
        return stencil.rk3_step_fused_pallas(chi, phi, pi, r, dx, dt)

    n = block + 2 * STEP_GHOST
    arr = jax.ShapeDtypeStruct((n,), DTYPE)
    scalar = jax.ShapeDtypeStruct((), DTYPE)
    return fn, (arr, arr, arr, arr, scalar, scalar)


def lower_block_step(block: int):
    """Lower the block-step for ``block`` to a jax ``Lowered`` object."""
    fn, specs = make_block_step_fn(block)
    return jax.jit(fn).lower(*specs)
