//! Task-granularity sweep on the 1+1D AMR problem (Fig 3/4 companion).
//!
//! The ParalleX AMR code exposes task granularity as a runtime parameter
//! — from whole-region blocks (Fig 4a, MPI-style) down to a single point
//! per task (Fig 4b). This example sweeps it on the real (inhomogeneous)
//! 1+1D problem and reports wallclock, thread counts and steals, showing
//! the overhead/starvation trade-off the paper describes.
//!
//!     cargo run --release --example granularity_sweep

use std::sync::Arc;

use parallex::amr::backend::NativeBackend;
use parallex::amr::dataflow_driver::{run, AmrConfig};
use parallex::amr::mesh::{Hierarchy, MeshConfig};
use parallex::amr::regrid::{initial_hierarchy, RegridConfig};
use parallex::metrics::{fmt_dur, Table};
use parallex::px::runtime::{PxConfig, PxRuntime};

fn main() {
    let base = initial_hierarchy(
        MeshConfig { r_max: 20.0, n0: 1601, levels: 1, cfl: 0.25, granularity: 64 },
        RegridConfig::default(),
        0.05,
        8.0,
        1.0,
    )
    .expect("hierarchy");
    let fine_regions = base.regions[1..].to_vec();
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    println!("granularity sweep: n0=1601, 1 refined level, {workers} workers, 12 coarse steps\n");
    let mut t = Table::new(&["granularity", "tasks", "threads", "steals", "wallclock", "pts/us"]);
    for g in [1usize, 2, 4, 8, 16, 32, 64, 128, 400, 1601] {
        let mesh = MeshConfig { granularity: g, ..base.config };
        let h = Hierarchy::build(mesh, &fine_regions).expect("build");
        let rt = PxRuntime::boot(PxConfig::smp(workers));
        let cfg = AmrConfig { amplitude: 0.05, coarse_steps: 12, ..Default::default() };
        let (plan, out) = run(&rt, h, Arc::new(NativeBackend), cfg).expect("run");
        let points: u64 = plan
            .plans
            .iter()
            .map(|p| p.info.width() as u64 * plan.targets[p.info.id.level as usize])
            .sum();
        let c = rt.counters_total();
        t.row(&[
            g.to_string(),
            out.tasks_run.to_string(),
            c.threads_spawned.to_string(),
            c.steals.to_string(),
            fmt_dur(out.elapsed),
            format!("{:.1}", points as f64 / out.elapsed.as_micros().max(1) as f64),
        ]);
        rt.shutdown();
    }
    println!("{}", t.render());
    println!("expected shape: throughput peaks at an intermediate granularity —");
    println!("tiny tasks pay scheduling overhead, huge tasks starve the workers.");
}
