//! Quickstart: evolve the paper's semilinear wave pulse with barrier-free
//! AMR on the ParalleX runtime, via the public API.
//!
//!     cargo run --release --example quickstart
//!
//! Walks the full stack: error-driven hierarchy construction (Fig 2),
//! the dataflow driver (no global timestep barrier), and the compute
//! backend (native here; swap to XLA with PX_BACKEND=xla to execute the
//! JAX/Pallas AOT artifacts through PJRT).


use parallex::amr::dataflow_driver::{run, AmrConfig};
use parallex::amr::mesh::MeshConfig;
use parallex::amr::physics::energy_norm;
use parallex::amr::regrid::{initial_hierarchy, RegridConfig};
use parallex::bench::backend_from_env;
use parallex::metrics::{ascii_profile, fmt_dur};
use parallex::px::runtime::{PxConfig, PxRuntime};
use parallex::util::err::{Error, Result};

fn main() -> Result<()> {
    if std::env::var("TF_CPP_MIN_LOG_LEVEL").is_err() {
        std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
    }
    // 1. Geometry: r in [0, 20], 801 base points, up to 2 refinement
    //    levels placed by the truncation-error estimator.
    let mesh = MeshConfig { r_max: 20.0, n0: 801, levels: 2, cfl: 0.25, granularity: 16 };
    let hierarchy =
        initial_hierarchy(mesh, RegridConfig::default(), 0.05, 8.0, 1.0).map_err(Error::msg)?;
    println!("hierarchy: {} levels, {} blocks", hierarchy.n_levels(), hierarchy.blocks.len());
    for (l, regs) in hierarchy.regions.iter().enumerate() {
        let dx = hierarchy.config.dx(l);
        let spans: Vec<String> = regs
            .iter()
            .map(|r| format!("[{:.2},{:.2}]", dx * r.lo as f64, dx * r.hi as f64))
            .collect();
        println!("  level {l}: dx={dx:.4} {}", spans.join(" "));
    }

    // 2. Boot a ParalleX runtime: one locality, all cores, work-stealing.
    let rt = PxRuntime::boot(PxConfig::default());

    // 3. Evolve 32 coarse steps with dataflow LCO synchronization only.
    let cfg = AmrConfig { amplitude: 0.05, coarse_steps: 32, ..Default::default() };
    let backend = backend_from_env();
    let (plan, outcome) = run(&rt, hierarchy, backend, cfg)?;

    // 4. Report.
    println!(
        "\nevolved {} tasks in {} on {} workers ({} PX-threads, {} steals)",
        outcome.tasks_run,
        fmt_dur(outcome.elapsed),
        rt.config().workers_per_locality,
        rt.counters_total().threads_spawned,
        rt.counters_total().steals,
    );
    let (reg0, f0) = outcome.region_state(&plan, 0, 0);
    let dx0 = plan.hierarchy.config.dx(0);
    let r: Vec<f64> = (reg0.lo..reg0.hi).map(|i| dx0 * i as f64).collect();
    println!("energy norm E = {:.6e}", energy_norm(&f0, &r, dx0));
    let series: Vec<(f64, f64)> = r.iter().zip(&f0.chi).map(|(x, y)| (*x, y.abs())).collect();
    println!("|chi(r)| after evolution:  |{}|", ascii_profile(&series, 64));
    rt.shutdown();
    Ok(())
}
