//! §V runtime-acceleration study: swap the thread manager's scheduling
//! queue for the simulated FPGA-offloaded queue and run the paper's
//! thread-intensive Fibonacci benchmark under each PCIe cost model.
//!
//!     cargo run --release --example fpga_offload

use std::sync::Arc;

use parallex::fpga::fib::{fib_value, run_fib};
use parallex::fpga::{FpgaQueue, PcieModel, FPGA_CLOCK_HZ, READ_4B_CYCLES};
use parallex::metrics::{fmt_dur, Table};
use parallex::px::counters::Counters;
use parallex::px::sched::GlobalQueue;

fn main() {
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8);
    let n = 22;
    println!(
        "SecV study: fib({n}), {workers} workers; FPGA clock {} MHz, 4B PCI read = {} cycles = {} ns\n",
        FPGA_CLOCK_HZ / 1_000_000,
        READ_4B_CYCLES,
        PcieModel::cycles_to_ns(READ_4B_CYCLES)
    );
    let mut t = Table::new(&["queue", "wallclock", "threads", "ns/thread", "bus-time", "ok"]);
    {
        let counters = Arc::new(Counters::default());
        let r = run_fib(n, workers, Box::new(GlobalQueue::new(counters.clone())), counters);
        t.row(&[
            "software global queue".into(),
            fmt_dur(r.elapsed),
            r.threads.to_string(),
            format!("{:.0}", r.ns_per_thread),
            "-".into(),
            (r.value == fib_value(n)).to_string(),
        ]);
    }
    for model in [PcieModel::measured_2011(), PcieModel::tuned_driver(), PcieModel::free()] {
        let counters = Arc::new(Counters::default());
        let q = FpgaQueue::new(model, counters.clone());
        let stats = q.stats.clone();
        let r = run_fib(n, workers, Box::new(q), counters);
        t.row(&[
            model.name.into(),
            fmt_dur(r.elapsed),
            r.threads.to_string(),
            format!("{:.0}", r.ns_per_thread),
            fmt_dur(std::time::Duration::from_nanos(
                stats.bus_ns.load(std::sync::atomic::Ordering::Relaxed),
            )),
            (r.value == fib_value(n)).to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("paper's result: the hardware queue matched / marginally beat software");
    println!("even with the 4-byte-read tax; fixing payloads is the projected win.");
}
