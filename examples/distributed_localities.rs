//! Multi-locality (distributed) execution: parcels over the simulated
//! interconnect, AGAS-resolved remote futures, split-phase transactions,
//! and migration with stale-cache forwarding.
//!
//!     cargo run --release --example distributed_localities
//!
//! The paper's inter-locality machinery (§II): work migrates via
//! continuations — a parcel names the action and its arguments, and the
//! receiving locality instantiates the PX-thread. Here four localities
//! cooperatively compute RK3 block-steps on remote data blocks, with the
//! wire modeled as a gigabit-era cluster interconnect.


use parallex::amr::physics::{initial_data, rk3_step, Fields};
use parallex::metrics::Table;
use parallex::px::gid::{Gid, GidKind};
use parallex::px::runtime::{PxConfig, PxRuntime};
use parallex::px::wire::{Dec, Enc};

/// Application action: run one RK3 step on a locality-resident block and
/// reply with the result on the continuation future (split-phase).
const ACT_STEP_BLOCK: u32 = 100;

fn main() {
    let rt = PxRuntime::boot(PxConfig::cluster(4, 2));
    println!(
        "booted {} localities x {} workers, wire: {:?}",
        rt.config().localities,
        rt.config().workers_per_locality,
        rt.config().net
    );

    // Register the application action on every locality (Fig 1's
    // "application specific components").
    rt.actions().register(ACT_STEP_BLOCK, |ctx, parcel| {
        let run = || -> parallex::px::PxResult<()> {
            let mut d = Dec::new(&parcel.args);
            let dx = d.f64()?;
            let dt = d.f64()?;
            let r0 = d.f64()?;
            // The block data lives in this locality's component store.
            let block = ctx.component::<Fields>(parcel.dest)?;
            let n = block.len();
            let r: Vec<f64> = (0..n).map(|i| r0 + dx * i as f64).collect();
            let out = rk3_step(&block.chi, &block.phi, &block.pi, &r, dx, dt);
            // Split-phase reply: resolve the caller's remote future.
            let mut payload = Vec::with_capacity(out.len() * 3);
            payload.extend_from_slice(&out.chi);
            payload.extend_from_slice(&out.phi);
            payload.extend_from_slice(&out.pi);
            ctx.set_remote_f64s(parcel.continuation, &payload)?;
            Ok(())
        };
        if let Err(e) = run() {
            eprintln!("ACT_STEP_BLOCK failed: {e}");
        }
    });

    // Place one data block on each non-root locality.
    let dx = 0.05;
    let dt = 0.0125;
    let n = 64;
    let mut blocks: Vec<(Gid, f64)> = Vec::new();
    for l in 1..4u32 {
        let r0 = 2.0 + l as f64 * 3.0;
        let r: Vec<f64> = (0..n).map(|i| r0 + dx * i as f64).collect();
        let data = initial_data(&r, 0.05, 8.0, 1.0);
        let gid = rt
            .locality(l)
            .register_component(GidKind::Block, data)
            .expect("register block");
        blocks.push((gid, r0));
    }

    // From locality 0, apply the step action to every remote block; the
    // replies arrive on remote futures (message-driven, no polling).
    let l0 = rt.locality(0).clone();
    let mut waits = Vec::new();
    for (gid, r0) in &blocks {
        let (k_gid, fut) = l0.new_remote_future().expect("future");
        let mut e = Enc::new();
        e.f64(dx).f64(dt).f64(*r0);
        l0.apply(*gid, ACT_STEP_BLOCK, e.finish(), k_gid).expect("apply");
        waits.push((*gid, *r0, fut));
    }
    let mut t = Table::new(&["block gid", "home", "r0", "out pts", "max|chi'|"]);
    for (gid, r0, fut) in waits {
        let v = fut.wait().expect("remote step");
        let m = v.len() / 3;
        let max = v[..m].iter().fold(0.0f64, |a, b| a.max(b.abs()));
        let home = l0.agas.resolve(gid).expect("resolve").locality;
        t.row(&[
            format!("{gid}"),
            format!("L{home}"),
            format!("{r0:.1}"),
            m.to_string(),
            format!("{max:.4e}"),
        ]);
    }
    println!("{}", t.render());

    // Migration: move block 0 to locality 2; a stale-cache apply from L0
    // is transparently forwarded by the AGAS protocol.
    let (gid, r0) = blocks[0];
    let obj = rt.locality(1).take_component(gid).expect("take");
    rt.locality(2).install_component(gid, obj);
    rt.locality(1).agas.migrate(gid, 2).expect("migrate");
    let (k_gid, fut) = l0.new_remote_future().expect("future");
    let mut e = Enc::new();
    e.f64(dx).f64(dt).f64(r0);
    l0.apply(gid, ACT_STEP_BLOCK, e.finish(), k_gid).expect("apply after migrate");
    let v = fut.wait().expect("post-migration step");
    println!(
        "after migration: {gid} now on L{}, step returned {} values (parcel was forwarded)",
        l0.agas.refresh(gid).expect("refresh").locality,
        v.len()
    );
    let c = rt.counters_total();
    println!(
        "parcels sent {}  received {}  bytes {}  threads-from-parcels {}",
        c.parcels_sent, c.parcels_received, c.parcel_bytes, c.threads_from_parcels
    );
    rt.shutdown();
}
