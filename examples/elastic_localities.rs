//! Elastic localities: the machine shrinks and grows *while* the AMR
//! dataflow graph runs — the ParalleX answer to CSP's frozen process
//! grid taken one step further than migration (DESIGN.md §8).
//!
//!     cargo run --release --example elastic_localities
//!
//! Boots a 4-locality runtime, starts a one-level AMR epoch, retires
//! localities 3 and 2 once ~30% of the tasks have completed (their
//! blocks drain onto the survivors through the AGAS migration protocol,
//! the wire drains, their parcel ports detach), then boots them back at
//! ~65% and repacks the remaining work across the full machine. The
//! physics is bitwise-identical to a run on a fixed machine.

use std::sync::Arc;

use parallex::amr::backend::NativeBackend;
use parallex::amr::dataflow_driver::{
    initial_block_states, run_epoch_elastic, AmrConfig,
};
use parallex::amr::engine::EpochPlan;
use parallex::amr::mesh::{Hierarchy, MeshConfig, Region};
use parallex::coordinator::{DistAmrOpts, MembershipPlan};
use parallex::metrics::Table;
use parallex::px::runtime::{PxConfig, PxRuntime};

fn main() {
    let rt = PxRuntime::boot(PxConfig::cluster(4, 2));
    println!(
        "booted roster of {} localities, members {:?}",
        rt.membership().capacity(),
        rt.membership().members()
    );

    let mesh = MeshConfig { r_max: 20.0, n0: 401, levels: 1, cfl: 0.25, granularity: 12 };
    let h = Hierarchy::build(mesh, &[vec![Region { lo: 240, hi: 400 }]]).expect("mesh");
    let cfg = AmrConfig { coarse_steps: 6, ..Default::default() };
    let plan = Arc::new(EpochPlan::new(h, cfg.coarse_steps));
    let init = initial_block_states(&plan, &cfg);

    // Retire L3+L2 at 30% of tasks done, boot them back at 65% — the
    // same script `px-amr dist --elastic "30:-3,30:-2,65:+2,65:+3"` runs.
    let mplan = MembershipPlan::parse("30:-3,30:-2,65:+2,65:+3").expect("script");
    let (out, stats) = run_epoch_elastic(
        &rt,
        plan,
        Arc::new(NativeBackend),
        cfg,
        &init,
        &DistAmrOpts::default(),
        &mplan,
    )
    .expect("elastic epoch");

    let mut t = Table::new(&["event", "at tasks", "blocks moved", "latency ms", "residents after"]);
    for ev in &stats.applied {
        t.row(&[
            ev.event.to_string(),
            ev.at_tasks.to_string(),
            ev.blocks_moved.to_string(),
            format!("{:.2}", ev.latency.as_secs_f64() * 1e3),
            ev.residents_after.to_string(),
        ]);
    }
    print!("{}", t.render());

    let totals = rt.counters_total();
    println!(
        "epoch done: tasks={} membership back to {:?}; {} blocks moved in {:.1} ms of rebalancing",
        out.tasks_run,
        rt.membership().members(),
        stats.blocks_moved,
        stats.rebalance_total.as_secs_f64() * 1e3,
    );
    println!(
        "parcels sent={} forwarded={} bounced={} dead_letters={} deep_copies={}",
        totals.parcels_sent,
        totals.parcels_forwarded,
        rt.net().bounced(),
        rt.net().dead_letters(),
        totals.payload_deep_copies,
    );
    assert_eq!(rt.membership().n_active(), 4, "grow events must restore the machine");
    assert_eq!(rt.net().dead_letters(), 0, "retirement must not lose parcels");
    assert_eq!(totals.payload_deep_copies, 0, "local pushes stay zero-copy");
    rt.shutdown();
    println!("ok");
}
