//! Criticality search: the paper's science driver.
//!
//! §III: "the amplitude A is tuned to explore criticality" — the
//! semilinear wave with p=7 exhibits a threshold A* between dispersal
//! (subcritical) and blow-up (supercritical). This example bisects A
//! over repeated barrier-free AMR evolutions, the same repeated-evolution
//! workload the paper's month-long searches perform (bounded here).
//!
//!     cargo run --release --example criticality_search

use std::sync::Arc;

use parallex::amr::backend::NativeBackend;
use parallex::amr::dataflow_driver::{run, AmrConfig};
use parallex::amr::mesh::MeshConfig;
use parallex::amr::regrid::{initial_hierarchy, RegridConfig};
use parallex::metrics::fmt_dur;
use parallex::px::runtime::{PxConfig, PxRuntime};

/// Classify an amplitude: true = supercritical (field blew up).
fn supercritical(rt: &PxRuntime, amplitude: f64, steps: u64) -> bool {
    let mesh = MeshConfig { r_max: 20.0, n0: 401, levels: 2, cfl: 0.25, granularity: 16 };
    let h = match initial_hierarchy(mesh, RegridConfig::default(), amplitude, 8.0, 1.0) {
        Ok(h) => h,
        Err(_) => return true, // refinement demands exploded
    };
    let cfg = AmrConfig { amplitude, coarse_steps: steps, ..Default::default() };
    match run(rt, h, Arc::new(NativeBackend), cfg) {
        Ok((plan, out)) => {
            // Diverged runs freeze early; also check the field magnitude.
            let (_, f0) = out.region_state(&plan, 0, 0);
            !f0.max_abs().is_finite()
                || f0.max_abs() > 10.0
                || out.min_steps(&plan, 0) < cfg.coarse_steps
        }
        Err(_) => true,
    }
}

fn main() {
    let t0 = std::time::Instant::now();
    let rt = PxRuntime::boot(PxConfig::default());
    let steps = 48;
    let (mut lo, mut hi) = (0.01, 1.2); // bracket: lo disperses, hi blows up
    assert!(!supercritical(&rt, lo, steps), "lower bracket must disperse");
    assert!(supercritical(&rt, hi, steps), "upper bracket must blow up");
    println!("bisecting critical amplitude A* in [{lo}, {hi}], {steps} coarse steps/run");
    for it in 0..12 {
        let mid = 0.5 * (lo + hi);
        let sup = supercritical(&rt, mid, steps);
        println!(
            "  iter {it:2}: A={mid:.6} -> {}   bracket [{lo:.6}, {hi:.6}]",
            if sup { "SUPERcritical" } else { "subcritical " }
        );
        if sup {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    println!(
        "\ncritical amplitude A* ~ {:.6} +- {:.1e}   ({} total)",
        0.5 * (lo + hi),
        0.5 * (hi - lo),
        fmt_dur(t0.elapsed())
    );
    rt.shutdown();
}
