//! Crash tolerance: a locality dies *unplanned* mid-epoch — no drain, no
//! goodbye parcel — and the run still completes bitwise-identically
//! (DESIGN.md §9).
//!
//!     cargo run --release --example crash_recovery
//!
//! Boots a 4-locality runtime with checkpointing on, then kills locality
//! 2 once ~35% of the tasks have completed: its heartbeats stop, its
//! parcel port is quarantined (in-flight parcels to it become dead
//! letters), and every task it was holding evaporates. The failure
//! detector declares the death after K missed heartbeats; the anchor
//! re-homes the lost blocks onto the survivors from the fragment-log
//! checkpoint, replays the captured dead letters, and the epoch finishes
//! on 3 localities with physics identical to an undisturbed run.

use std::sync::Arc;

use parallex::amr::backend::NativeBackend;
use parallex::amr::dataflow_driver::{
    initial_block_states, run_epoch, run_epoch_crash, AmrConfig, KillSpec,
};
use parallex::amr::engine::EpochPlan;
use parallex::amr::mesh::{Hierarchy, MeshConfig, Region};
use parallex::coordinator::DistAmrOpts;
use parallex::metrics::Table;
use parallex::px::runtime::{PxConfig, PxRuntime};

fn main() {
    let mesh = MeshConfig { r_max: 20.0, n0: 401, levels: 1, cfl: 0.25, granularity: 12 };
    let h = Hierarchy::build(mesh, &[vec![Region { lo: 240, hi: 400 }]]).expect("mesh");
    let cfg = AmrConfig { coarse_steps: 6, ..Default::default() };
    let plan = Arc::new(EpochPlan::new(h, cfg.coarse_steps));
    let init = initial_block_states(&plan, &cfg);

    // The undisturbed answer: one locality, nothing to kill.
    let reference = {
        let rt = PxRuntime::boot(PxConfig::smp(2));
        let out = run_epoch(&rt, plan.clone(), Arc::new(NativeBackend), cfg, &init)
            .expect("reference epoch");
        rt.shutdown();
        out
    };

    let rt = PxRuntime::boot(PxConfig::cluster(4, 2));
    println!(
        "booted roster of {} localities, members {:?} — killing L2 at 35%",
        rt.membership().capacity(),
        rt.membership().members()
    );

    // The anchor (locality 0) can never be killed — it hosts the AGAS
    // service and the recovery machinery. Everyone else is fair game.
    let err = run_epoch_crash(
        &rt,
        plan.clone(),
        Arc::new(NativeBackend),
        cfg,
        &init,
        &DistAmrOpts::default(),
        KillSpec { victim: 0, at_fraction: 0.5 },
    )
    .expect_err("anchor death must fail fast");
    println!("killing the anchor fails fast: {err}");

    let (out, stats) = run_epoch_crash(
        &rt,
        plan,
        Arc::new(NativeBackend),
        cfg,
        &init,
        &DistAmrOpts::default(),
        KillSpec { victim: 2, at_fraction: 0.35 },
    )
    .expect("crash epoch");

    let mut t = Table::new(&["what", "value"]);
    t.row(&["killed".into(), format!("L{} (at task {})", stats.killed, stats.at_tasks)]);
    t.row(&[
        "detection latency".into(),
        format!("{:.2} ms", stats.detection_latency.as_secs_f64() * 1e3),
    ]);
    t.row(&[
        "recovery latency".into(),
        format!("{:.2} ms", stats.recovery_latency.as_secs_f64() * 1e3),
    ]);
    t.row(&["blocks recovered".into(), stats.blocks_recovered.to_string()]);
    t.row(&["fragments replayed".into(), stats.fragments_replayed.to_string()]);
    t.row(&["dead letters replayed".into(), stats.parcels_replayed.to_string()]);
    t.row(&["heartbeats missed".into(), stats.heartbeats_missed.to_string()]);
    print!("{}", t.render());

    let totals = rt.counters_total();
    println!(
        "epoch done on survivors {:?}: tasks={} parcels sent={} received={} replayed={}",
        rt.membership().members(),
        out.tasks_run,
        totals.parcels_sent,
        totals.parcels_received,
        totals.parcels_replayed,
    );
    assert!(reference.bitwise_eq(&out), "recovery must not perturb the physics");
    assert!(!rt.membership().is_member(2), "the victim stays dead");
    assert!(stats.blocks_recovered >= 1, "the victim held work when it died");
    assert_eq!(rt.net().dead_letters(), 0, "every captured parcel must be replayed");
    assert_eq!(totals.payload_deep_copies, 0, "recovery stays zero-copy locally");
    rt.shutdown();
    println!("ok: unplanned death of L2 recovered, physics bitwise-identical");
}
